//! Tests for the optimal sequencer: optimality vs exhaustive checks, the
//! Figure-1 example, cost caps (Figure 2), training-mode costs, and the
//! Theorem 1/2 cheaper-than-naive guarantees as property tests.

use super::*;
use crate::einsum::parse;
use crate::util::prop;

fn plan(expr: &str, dims: Vec<Vec<usize>>, opts: &PlanOptions) -> Plan {
    contract_path(expr, &dims, opts).unwrap()
}

#[test]
fn matmul_chain_picks_cheap_side() {
    // (A·B)·C vs A·(B·C): A 2×100, B 100×100, C 100×2.
    // A(BC): 100·100·2 + 2·100·2 = 20_400; (AB)C: 2·100·100 + 2·100·2 = 20_400.
    // Make it asymmetric: A 2×3, B 3×100, C 100×2:
    //   (AB)C = 2·3·100 + 2·100·2 = 1000;  A(BC) = 3·100·2 + 2·3·2 = 612.
    let p = plan(
        "ij,jk,kl->il",
        vec![vec![2, 3], vec![3, 100], vec![100, 2]],
        &PlanOptions::default(),
    );
    assert_eq!(p.cost, 612.0);
    assert_eq!(p.steps.len(), 2);
    // LTR is the worse order here.
    assert_eq!(p.naive_cost, 1000.0);
    assert!(p.speedup_vs_naive() > 1.0);
}

#[test]
fn ltr_strategy_reports_itself() {
    let opts = PlanOptions {
        strategy: Strategy::LeftToRight,
        ..Default::default()
    };
    let p = plan(
        "ij,jk,kl->il",
        vec![vec![2, 3], vec![3, 100], vec![100, 2]],
        &opts,
    );
    assert_eq!(p.cost, p.naive_cost);
    assert_eq!(p.cost, 1000.0);
}

#[test]
fn optimal_no_worse_than_greedy_and_ltr() {
    let dims = vec![
        vec![4, 7, 9],
        vec![10, 5],
        vec![5, 4, 2],
        vec![6, 8, 9, 2],
    ];
    let expr = "ijk,jl,lmq,njpq->ijknp|j";
    let o = plan(expr, dims.clone(), &PlanOptions::default());
    let g = plan(
        expr,
        dims.clone(),
        &PlanOptions {
            strategy: Strategy::Greedy,
            ..Default::default()
        },
    );
    let l = plan(
        expr,
        dims,
        &PlanOptions {
            strategy: Strategy::LeftToRight,
            ..Default::default()
        },
    );
    assert!(o.cost <= g.cost + 1e-9);
    assert!(o.cost <= l.cost + 1e-9);
}

#[test]
fn fig1_example_beats_naive() {
    // Figure 1a/1b: A(4,7,9), B(10,5), C(5,4,2), D(6,8,9,2),
    // "ijk,jl,lmq,njpq->ijknp|j": optimized ≈ half the naive count.
    let dims = vec![
        vec![4, 7, 9],
        vec![10, 5],
        vec![5, 4, 2],
        vec![6, 8, 9, 2],
    ];
    let p = plan("ijk,jl,lmq,njpq->ijknp|j", dims, &PlanOptions::default());
    assert!(
        p.cost < p.naive_cost,
        "optimal {} !< naive {}",
        p.cost,
        p.naive_cost
    );
    // The report renders without panicking and carries the headline rows.
    let rep = p.report();
    assert!(rep.contains("Complete sequence"));
    assert!(rep.contains("Naive FLOP count"));
    assert!(rep.contains("Optimized FLOP count"));
    assert!(rep.contains("Largest intermediate"));
}

#[test]
fn exhaustive_agreement_on_small_networks() {
    // For 4-input networks the DP must match brute-force enumeration of all
    // contraction trees. Brute force: recursively split the operand set.
    fn all_trees_cost(
        ctx: &NetCtx,
        mask: u64,
        training: bool,
        memo: &mut std::collections::HashMap<u64, f64>,
    ) -> f64 {
        if mask.count_ones() == 1 {
            return 0.0;
        }
        if let Some(&c) = memo.get(&mask) {
            return c;
        }
        let mut best = f64::INFINITY;
        let low = mask & mask.wrapping_neg();
        let mut s = (mask - 1) & mask;
        while s != 0 {
            if s & low != 0 {
                let t = mask ^ s;
                let ca = all_trees_cost(ctx, s, training, memo);
                let cb = all_trees_cost(ctx, t, training, memo);
                let merge = analyze_merge(ctx, &ctx.subset(s), &ctx.subset(t));
                best = best.min(ca + cb + merge.dims.mults(training));
            }
            s = (s - 1) & mask;
        }
        memo.insert(mask, best);
        best
    }

    for (expr, dims) in [
        (
            "ijk,jl,lmq,njpq->ijknp|j",
            vec![vec![4, 7, 9], vec![10, 5], vec![5, 4, 2], vec![6, 8, 9, 2]],
        ),
        (
            "bsh,rt,rs,rh->bth|h",
            vec![vec![2, 3, 16], vec![4, 5], vec![4, 3], vec![4, 3]],
        ),
    ] {
        let spec = parse(expr).unwrap();
        let sized = crate::einsum::SizedSpec::new(spec, dims.clone()).unwrap();
        let ctx = NetCtx::new(&sized);
        let full = (1u64 << sized.spec.n_inputs()) - 1;
        let mut memo = std::collections::HashMap::new();
        let brute = all_trees_cost(&ctx, full, false, &mut memo);
        let p = plan(expr, dims, &PlanOptions::default());
        assert!(
            (p.cost - brute).abs() < 1e-6,
            "{expr}: dp={} brute={}",
            p.cost,
            brute
        );
    }
}

#[test]
fn cost_cap_restricts_steps() {
    // Force the planner away from the globally-optimal tree by capping the
    // per-step cost below the optimum's largest step (Fig. 2 orange path).
    let dims = vec![vec![2, 3], vec![3, 100], vec![100, 2]];
    let expr = "ij,jk,kl->il";
    let p = plan(expr, dims.clone(), &PlanOptions::default());
    let max_step = p.steps.iter().map(|s| s.cost).fold(0.0, f64::max);
    // A generous cap keeps the same plan feasible.
    let capped = plan(
        expr,
        dims.clone(),
        &PlanOptions {
            cost_cap: Some(max_step),
            ..Default::default()
        },
    );
    assert_eq!(capped.cost, p.cost);
    // An impossible cap errors out.
    let err = contract_path(
        expr,
        &dims,
        &PlanOptions {
            cost_cap: Some(1.0),
            ..Default::default()
        },
    );
    assert!(err.is_err());
}

#[test]
fn cost_cap_can_force_suboptimal_path() {
    // Construct a network where the optimal tree has one expensive step but
    // an alternative tree spreads cost more evenly.
    // A: i×j (2×2), B: j×k (2×512), C: k×l (512×2)
    // optimal: B·C first (2·512·2 = 2048) then A·(BC) (2·2·2 = 8) → 2056,
    //   max step 2048.
    // capped at 2047: must pick (A·B) first (2·2·512=2048)... also 2048.
    // Use asymmetric sizes instead: A 1×2, B 2×512, C 512×2:
    //   (AB)C: 1·2·512 + 1·512·2 = 2048, max step 1024.
    //   A(BC): 2·512·2 + 1·2·2 = 2052, max step 2048.
    let dims = vec![vec![1, 2], vec![2, 512], vec![512, 2]];
    let expr = "ij,jk,kl->il";
    let p = plan(expr, dims.clone(), &PlanOptions::default());
    assert_eq!(p.cost, 2048.0); // (AB)C
    let capped = plan(
        expr,
        dims,
        &PlanOptions {
            cost_cap: Some(1100.0),
            ..Default::default()
        },
    );
    assert_eq!(capped.cost, 2048.0);
    assert!(capped.steps.iter().all(|s| s.cost <= 1100.0));
}

#[test]
fn training_cost_at_least_forward() {
    let dims = vec![vec![2, 3, 8, 8], vec![4, 2], vec![4, 3], vec![4, 3], vec![4, 3]];
    let expr = "bshw,rt,rs,rh,rw->bthw|hw";
    let fwd = plan(expr, dims.clone(), &PlanOptions::default());
    let trn = plan(
        expr,
        dims,
        &PlanOptions {
            training: true,
            ..Default::default()
        },
    );
    assert!(trn.cost >= fwd.cost * 2.0, "training should roughly 3x fwd");
}

#[test]
fn plan_json_roundtrips() {
    let p = plan(
        "ij,jk->ik",
        vec![vec![2, 3], vec![3, 4]],
        &PlanOptions::default(),
    );
    let j = p.to_json();
    let parsed = crate::util::json::parse(&j.encode()).unwrap();
    assert_eq!(parsed.get("cost").unwrap().as_f64(), Some(24.0));
    assert_eq!(
        parsed.get("steps").unwrap().as_arr().unwrap().len(),
        1
    );
}

#[test]
fn greedy_handles_many_inputs() {
    // 20-input chain falls back to greedy under Optimal (max_dp_inputs=16).
    let n = 20;
    let mut parts = Vec::new();
    let letters: Vec<char> = "abcdefghijklmnopqrstu".chars().collect();
    for i in 0..n {
        parts.push(format!("{}{}", letters[i], letters[i + 1]));
    }
    let expr = format!("{}->{}{}", parts.join(","), letters[0], letters[n]);
    let dims: Vec<Vec<usize>> = (0..n).map(|_| vec![2, 2]).collect();
    let p = plan(&expr, dims, &PlanOptions::default());
    assert_eq!(p.steps.len(), n - 1);
}

#[test]
fn property_theorem1_cp_reduction() {
    // Theorem 1: for RCP layers with H'≫H, W'≫W and R ≥ S there is a
    // pairwise path cheaper than naive left-to-right. We verify the
    // sequencer finds one for random hypothesis-satisfying shapes.
    prop::check("theorem1-cp-reduction", 25, |g| {
        let m = g.usize_in(2, 3); // reshaping factor M
        let tms: Vec<usize> = (0..m).map(|_| g.usize_in(2, 3)).collect();
        let sms: Vec<usize> = (0..m).map(|_| g.usize_in(2, 3)).collect();
        let s: usize = sms.iter().product();
        let r = s + g.usize_in(0, 4); // R ≥ S
        let h = g.usize_in(2, 3);
        let hp = h * g.usize_in(6, 10); // H' ≫ H
        let b = g.usize_in(1, 4);

        // Build "b(s1)…(sM)hw, r(t1)(s1),…, rhw -> b(t1)…(tM)hw|hw"
        let mut lhs = vec![format!(
            "b{}hw",
            (1..=m).map(|i| format!("(s{i})")).collect::<String>()
        )];
        for i in 1..=m {
            lhs.push(format!("r(t{i})(s{i})"));
        }
        lhs.push("rhw".to_string());
        let out = format!(
            "b{}hw",
            (1..=m).map(|i| format!("(t{i})")).collect::<String>()
        );
        let expr = format!("{}->{}|hw", lhs.join(","), out);

        let mut dims = vec![{
            let mut d = vec![b];
            d.extend(&sms);
            d.push(hp);
            d.push(hp);
            d
        }];
        for i in 0..m {
            dims.push(vec![r, tms[i], sms[i]]);
        }
        dims.push(vec![r, h, h]);

        let p = plan(&expr, dims, &PlanOptions::default());
        assert!(
            p.cost < p.naive_cost,
            "theorem 1 violated: opt {} !< naive {} for {}",
            p.cost,
            p.naive_cost,
            expr
        );
    });
}

#[test]
fn property_theorem2_tucker_reduction() {
    // Theorem 2: analogous guarantee for reshaped Tucker layers.
    prop::check("theorem2-tucker-reduction", 20, |g| {
        let m = g.usize_in(2, 3);
        let tms: Vec<usize> = (0..m).map(|_| g.usize_in(2, 3)).collect();
        let sms: Vec<usize> = (0..m).map(|_| g.usize_in(2, 3)).collect();
        let s: usize = sms.iter().product();
        // ranks with ∏ R_m ≥ S
        let mut rms: Vec<usize> = (0..m).map(|_| g.usize_in(2, 3)).collect();
        while rms.iter().product::<usize>() < s {
            let k = g.usize_in(0, m - 1);
            rms[k] += 1;
        }
        let r0 = g.usize_in(2, 4);
        let h = g.usize_in(2, 3);
        let hp = h * g.usize_in(6, 10);
        let b = g.usize_in(1, 3);

        let mut lhs = vec![format!(
            "b{}hw",
            (1..=m).map(|i| format!("(s{i})")).collect::<String>()
        )];
        for i in 1..=m {
            lhs.push(format!("(r{i})(t{i})(s{i})"));
        }
        lhs.push("(r0)hw".to_string());
        lhs.push(format!(
            "(r0){}",
            (1..=m).map(|i| format!("(r{i})")).collect::<String>()
        ));
        let out = format!(
            "b{}hw",
            (1..=m).map(|i| format!("(t{i})")).collect::<String>()
        );
        let expr = format!("{}->{}|hw", lhs.join(","), out);

        let mut dims = vec![{
            let mut d = vec![b];
            d.extend(&sms);
            d.push(hp);
            d.push(hp);
            d
        }];
        for i in 0..m {
            dims.push(vec![rms[i], tms[i], sms[i]]);
        }
        dims.push(vec![r0, h, h]);
        {
            let mut d = vec![r0];
            d.extend(&rms);
            dims.push(d);
        }

        let p = plan(&expr, dims, &PlanOptions::default());
        assert!(
            p.cost < p.naive_cost,
            "theorem 2 violated: opt {} !< naive {} for {}",
            p.cost,
            p.naive_cost,
            expr
        );
    });
}

#[test]
fn greedy_matches_optimal_cost_on_small_specs() {
    // Regression for the greedy scan caching the winning Merge instead of
    // re-running analyze_merge after selection: the selected pair (and
    // therefore the whole tree) must be unchanged. On these specs the
    // greedy tree is also exactly optimal (hand-verified costs).
    for (expr, dims, want) in [
        // (B·C) first (tiebreak on output elems), then A·(BC): 600 + 12.
        (
            "ij,jk,kl->il",
            vec![vec![2, 3], vec![3, 100], vec![100, 2]],
            612.0,
        ),
        // Single pairwise step: trivially identical. g·t·n·s = 2·4·5·3.
        ("bci,bcj->bij", vec![vec![2, 3, 4], vec![2, 3, 5]], 120.0),
        // Four identical batch operands: every tree costs 3 · (2·3) = 18.
        (
            "ab,ab,ab,ab->ab",
            vec![vec![2, 3], vec![2, 3], vec![2, 3], vec![2, 3]],
            18.0,
        ),
    ] {
        let o = plan(expr, dims.clone(), &PlanOptions::default());
        let g = plan(
            expr,
            dims,
            &PlanOptions {
                strategy: Strategy::Greedy,
                ..Default::default()
            },
        );
        assert_eq!(g.cost, want, "{expr}: greedy cost");
        assert_eq!(o.cost, want, "{expr}: optimal cost");
        assert_eq!(g.steps.len(), o.steps.len());
    }
}

#[test]
fn max_dp_inputs_boundary_switches_to_greedy() {
    // 4-input chain where greedy (80) is strictly worse than the DP
    // optimum (76 = A·(B·(C·D))): at the boundary (max_dp_inputs == n) the
    // Optimal strategy must run the exact DP; just below it, it must fall
    // back to greedy — and both must plan without error.
    let expr = "ab,bc,cd,de->ae";
    let dims = vec![vec![2, 3], vec![3, 4], vec![4, 5], vec![5, 2]];
    let exact = plan(
        expr,
        dims.clone(),
        &PlanOptions {
            max_dp_inputs: 4,
            ..Default::default()
        },
    );
    assert_eq!(exact.cost, 76.0, "DP at the boundary must find the optimum");
    let fallback = plan(
        expr,
        dims.clone(),
        &PlanOptions {
            max_dp_inputs: 3,
            ..Default::default()
        },
    );
    assert_eq!(fallback.cost, 80.0, "below the boundary falls back to greedy");
    assert!(exact.cost <= fallback.cost);
    // The explicit Greedy strategy agrees with the fallback.
    let greedy = plan(
        expr,
        dims,
        &PlanOptions {
            strategy: Strategy::Greedy,
            ..Default::default()
        },
    );
    assert_eq!(greedy.cost, fallback.cost);
}

#[test]
fn plan_rejects_more_than_63_inputs() {
    // The old DP special-cased n == 64 with a u64::MAX full mask, under
    // which `for mask in 1..=full` would never have terminated; plan_with
    // must reject such sizes outright (and the DP now computes its mask
    // checked).
    let expr = format!("{}->i", vec!["i"; 64].join(","));
    let dims = vec![vec![2]; 64];
    let err = contract_path(&expr, &dims, &PlanOptions::default());
    assert!(err.is_err());
    assert!(
        err.unwrap_err().contains("too many inputs"),
        "should reject 64 inputs at the plan_with gate"
    );
    // 63 inputs is within the representable range and must plan fine
    // (greedy fallback; DP would be astronomically large).
    let expr63 = format!("{}->i", vec!["i"; 63].join(","));
    let dims63 = vec![vec![2]; 63];
    let p = contract_path(&expr63, &dims63, &PlanOptions::default()).unwrap();
    assert_eq!(p.steps.len(), 62);
}

#[test]
fn raised_max_dp_inputs_degrades_to_greedy_beyond_hard_cap() {
    // A max_dp_inputs above the DP's hard feasibility ceiling must not
    // error: dispatch clamps and falls back to greedy like every other
    // over-limit case.
    let expr = format!("{}->i", vec!["i"; 40].join(","));
    let dims = vec![vec![2]; 40];
    let p = contract_path(
        &expr,
        &dims,
        &PlanOptions {
            max_dp_inputs: 63,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(p.steps.len(), 39);
}

#[test]
fn plan_records_requested_backend() {
    use crate::exec::Backend;
    let dims = vec![vec![2, 3], vec![3, 4]];
    let default_plan = plan("ij,jk->ik", dims.clone(), &PlanOptions::default());
    assert_eq!(default_plan.backend, Backend::Parallel { threads: 0 });
    let scalar_plan = plan(
        "ij,jk->ik",
        dims,
        &PlanOptions {
            backend: Backend::Scalar,
            ..Default::default()
        },
    );
    assert_eq!(scalar_plan.backend, Backend::Scalar);
}

#[test]
fn subset_order_independence() {
    // The SubSpec of a mask must match incremental merging in any order.
    let spec = parse("bfsh,fgh,sth->bgth|h").unwrap();
    let sized = crate::einsum::SizedSpec::new(
        spec,
        vec![vec![2, 3, 4, 8], vec![3, 5, 3], vec![4, 6, 2]],
    )
    .unwrap();
    let ctx = NetCtx::new(&sized);
    // mask {0,1,2} via ((0,1),2) and ((1,2),0):
    let m01 = analyze_merge(&ctx, &ctx.leaf(0), &ctx.leaf(1));
    let m01_2 = analyze_merge(&ctx, &m01.result, &ctx.leaf(2));
    let m12 = analyze_merge(&ctx, &ctx.leaf(1), &ctx.leaf(2));
    let m12_0 = analyze_merge(&ctx, &ctx.leaf(0), &m12.result);
    assert_eq!(m01_2.result.modes, m12_0.result.modes);
    assert_eq!(m01_2.result.sizes, m12_0.result.sizes);
    assert_eq!(m01_2.result, ctx.subset(0b111));
}

#[test]
fn strategy_display_parse_round_trips() {
    let variants = [
        Strategy::Optimal,
        Strategy::Greedy,
        Strategy::LeftToRight,
        Strategy::Measured { top_k: 1 },
        Strategy::Measured { top_k: 3 },
        Strategy::Measured {
            top_k: DEFAULT_MEASURED_TOP_K,
        },
    ];
    for s in variants {
        let rendered = s.to_string();
        let parsed: Strategy = rendered.parse().unwrap_or_else(|e| {
            panic!("'{rendered}' failed to parse back: {e}");
        });
        assert_eq!(parsed, s, "round-trip through '{rendered}'");
    }
    // Shorthands.
    assert_eq!("ltr".parse::<Strategy>().unwrap(), Strategy::LeftToRight);
    assert_eq!(
        "measured".parse::<Strategy>().unwrap(),
        Strategy::Measured {
            top_k: DEFAULT_MEASURED_TOP_K
        }
    );
    assert_eq!(
        " optimal ".parse::<Strategy>().unwrap(),
        Strategy::Optimal,
        "surrounding whitespace is tolerated"
    );
}

#[test]
fn unknown_strategy_strings_are_structured_errors() {
    for bad in [
        "fastest",
        "",
        "Optimal",
        "measured:",
        "measured:0",
        "measured:-1",
        "measured:3x",
        "measured: 3",
    ] {
        let err = bad
            .parse::<Strategy>()
            .expect_err("must reject unknown strategy strings");
        assert_eq!(err.input, bad.trim(), "error preserves the input");
        let msg = err.to_string();
        assert!(
            msg.contains("unknown strategy") && msg.contains("measured[:K]"),
            "error message lists the accepted forms: {msg}"
        );
    }
}

#[test]
fn measured_candidates_are_flops_ordered_with_canonical_first() {
    // 3-input matmul chain with a strongly preferred association order.
    let sized = crate::einsum::SizedSpec::new(
        parse("ij,jk,kl->il").unwrap(),
        vec![vec![2, 64], vec![64, 64], vec![64, 2]],
    )
    .unwrap();
    let opts = PlanOptions::default();
    let cands = candidate_plans(&sized, &opts, 3).unwrap();
    assert!(cands.len() >= 2, "expected mirrors or multiple trees");
    // Candidate 0 is the FLOPs-optimal plan.
    let optimal = plan(
        "ij,jk,kl->il",
        vec![vec![2, 64], vec![64, 64], vec![64, 2]],
        &opts,
    );
    assert_eq!(cands[0].cost, optimal.cost);
    // FLOPs-ascending over tree pairs: every candidate costs at least as
    // much as candidate 0, and costs never decrease across tree groups.
    for c in &cands {
        assert!(c.cost >= cands[0].cost);
    }
    // Signatures are unique (mirrors differ in operand order).
    let mut sigs: Vec<String> = cands.iter().map(|p| p.signature()).collect();
    sigs.sort();
    sigs.dedup();
    assert_eq!(sigs.len(), cands.len(), "candidate signatures collide");
    // Candidates carry no tuning-generation stamp (only the measured
    // selection result is stamped).
    for c in &cands {
        assert_eq!(c.tuning_generation, None);
    }
}

#[test]
fn measured_strategy_falls_back_to_analytic_on_cache_miss() {
    // Fresh expression: nothing measured in any context, so the measured
    // planner must reproduce the analytic (optimal) tree choice and cost.
    let dims = vec![vec![3, 17], vec![17, 29], vec![29, 5]];
    let optimal = plan("ab,bc,cd->ad", dims.clone(), &PlanOptions::default());
    let measured = plan(
        "ab,bc,cd->ad",
        dims,
        &PlanOptions {
            strategy: Strategy::Measured { top_k: 4 },
            ..Default::default()
        },
    );
    assert_eq!(measured.cost, optimal.cost);
    assert_eq!(measured.strategy, Strategy::Measured { top_k: 4 });
    assert!(
        measured.tuning_generation.is_some(),
        "measured plans are generation-stamped"
    );
}
