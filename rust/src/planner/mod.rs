//! The optimal sequencer (paper §3.2): decomposes an N-input conv_einsum
//! into a FLOPs-minimal sequence of pairwise operations.
//!
//! netcon [Pfeifer–Haegeman–Verstraete 2014] searches the space of pairwise
//! contraction trees; our extension swaps its contraction-cost function for
//! the tnn-cost model ([`crate::cost`]) which prices convolutions (Eq. 8)
//! and, in training mode, the backward computations `g1`/`g2`.
//!
//! Strategies:
//! * [`Strategy::Optimal`] — exact subset dynamic program (equivalent
//!   optimum to netcon's breadth-first search; `O(3^n)` over input subsets).
//! * [`Strategy::Greedy`] — cheapest-pair-first heuristic, for very large
//!   networks.
//! * [`Strategy::LeftToRight`] — the paper's naive baseline.
//!
//! A [`PlanOptions::cost_cap`] restricts the search to trees whose every
//! step costs at most the cap — the "orange path" of the paper's Figure 2.

mod subspec;

pub use subspec::{analyze_merge, step_sized_spec, Merge, NetCtx, SubSpec};

use crate::cost::flat_cost;
use crate::einsum::{parse, ConvKind, SizedSpec};
use crate::exec::Backend;
use crate::util::json::Json;
use crate::util::sci;

/// Evaluation-order search strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Exact FLOPs-minimal tree (netcon-equivalent subset DP).
    Optimal,
    /// Cheapest-pair-first heuristic.
    Greedy,
    /// Naive left-to-right evaluation — the paper's baseline.
    LeftToRight,
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Strategy::Optimal => "optimal",
            Strategy::Greedy => "greedy",
            Strategy::LeftToRight => "left-to-right",
        };
        f.write_str(s)
    }
}

/// Options controlling planning.
#[derive(Debug, Clone)]
pub struct PlanOptions {
    pub strategy: Strategy,
    /// Price steps with the training cost `f + g1 + g2` (Appendix B) rather
    /// than forward-only.
    pub training: bool,
    /// Reject any tree containing a step costlier than this (paper Fig. 2).
    pub cost_cap: Option<f64>,
    /// Explicit convolution varieties (parallel to the pipe list); `None`
    /// uses the defaults (Same for 2-input modes, Circular for multi-way).
    pub conv_kinds: Option<Vec<ConvKind>>,
    /// Above this input count, Optimal falls back to Greedy.
    pub max_dp_inputs: usize,
    /// Execution backend recorded on the plan (used by `execute_path` and
    /// the autodiff tape; see [`crate::exec::Backend`]).
    pub backend: Backend,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            strategy: Strategy::Optimal,
            training: false,
            cost_cap: None,
            conv_kinds: None,
            max_dp_inputs: 16,
            backend: Backend::default(),
        }
    }
}

/// One pairwise step of a plan, in opt-einsum working-list semantics:
/// operands `lhs`/`rhs` are removed from the list and the result appended.
#[derive(Debug, Clone)]
pub struct PlanStep {
    pub lhs: usize,
    pub rhs: usize,
    /// The executable 2-input spec for this step.
    pub sized: SizedSpec,
    /// Circular wrap moduli per conv mode of the step.
    pub moduli: Vec<Option<usize>>,
    /// Rendered einsum string of the step (for display / goldens).
    pub expr: String,
    /// Multiplications (under the plan's cost mode).
    pub cost: f64,
    /// Elements of the step output.
    pub out_elems: f64,
}

/// A complete evaluation plan for an N-input conv_einsum.
#[derive(Debug, Clone)]
pub struct Plan {
    pub expr: String,
    pub n_inputs: usize,
    pub strategy: Strategy,
    pub training: bool,
    /// Execution backend the plan was made for (overridable at execution
    /// time via `execute_path_with`).
    pub backend: Backend,
    pub steps: Vec<PlanStep>,
    /// Permutation from the last step's (mode-sorted) output to the
    /// requested output order.
    pub final_perm: Option<Vec<usize>>,
    /// Total cost of this plan (multiplications).
    pub cost: f64,
    /// Cost of the naive left-to-right baseline, for the report.
    pub naive_cost: f64,
    /// Single-nested-loop cost (opt-einsum's "naive FLOP count").
    pub flat_cost: f64,
    /// Largest intermediate produced, in elements.
    pub largest_intermediate: f64,
    /// Peak simultaneously-live elements during forward execution
    /// (inputs + working list + current output).
    pub peak_mem_elems: f64,
}

impl Plan {
    /// Speedup of this plan over left-to-right.
    pub fn speedup_vs_naive(&self) -> f64 {
        self.naive_cost / self.cost.max(1.0)
    }

    /// Paper-Figure-1b-style report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("  Complete sequence:  {}\n", self.expr));
        s.push_str(&format!("  Naive FLOP count:  {}\n", sci(self.naive_cost)));
        s.push_str(&format!("  Optimized FLOP count:  {}\n", sci(self.cost)));
        s.push_str(&format!(
            "  Largest intermediate:  {} elements\n",
            sci(self.largest_intermediate)
        ));
        s.push_str(&format!("  Strategy: {}", self.strategy));
        if self.training {
            s.push_str("  (training cost model: f + g1 + g2)");
        }
        s.push('\n');
        s.push_str("--------------------------------------------------\n");
        s.push_str("current\n");
        s.push_str("--------------------------------------------------\n");
        for step in &self.steps {
            s.push_str(&format!(
                "{:<40} cost {:>10}  out {:>10}\n",
                step.expr,
                sci(step.cost),
                sci(step.out_elems)
            ));
        }
        s
    }

    /// JSON form (used by golden tests against the python planner mirror).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("expr", Json::str(&self.expr)),
            ("strategy", Json::str(format!("{}", self.strategy))),
            ("training", Json::Bool(self.training)),
            ("cost", Json::num(self.cost)),
            ("naive_cost", Json::num(self.naive_cost)),
            ("flat_cost", Json::num(self.flat_cost)),
            ("largest_intermediate", Json::num(self.largest_intermediate)),
            ("peak_mem_elems", Json::num(self.peak_mem_elems)),
            (
                "steps",
                Json::arr(self.steps.iter().map(|st| {
                    Json::obj(vec![
                        ("lhs", Json::num(st.lhs as f64)),
                        ("rhs", Json::num(st.rhs as f64)),
                        ("expr", Json::str(&st.expr)),
                        ("cost", Json::num(st.cost)),
                        ("out_elems", Json::num(st.out_elems)),
                    ])
                })),
            ),
        ])
    }
}

/// Plan a parsed + sized expression.
pub fn plan_with(sized: &SizedSpec, opts: &PlanOptions) -> Result<Plan, String> {
    let n = sized.spec.n_inputs();
    if n < 2 {
        return Err("planning requires at least 2 inputs".to_string());
    }
    if n > 63 {
        return Err(format!("too many inputs ({n} > 63)"));
    }
    // Re-bind conv kinds if the options override them.
    let owned;
    let sized = match &opts.conv_kinds {
        Some(kinds) => {
            owned = SizedSpec::with_kinds(sized.spec.clone(), sized.dims.clone(), kinds.clone())?;
            &owned
        }
        None => sized,
    };
    let ctx = NetCtx::new(sized);

    // The left-to-right baseline is always computed for the report.
    let ltr_tree = left_to_right_tree(n);
    let ltr_cost = tree_cost(&ctx, &ltr_tree, opts.training, None)
        .ok_or("internal: LTR tree must be feasible")?;

    let tree = match opts.strategy {
        Strategy::LeftToRight => ltr_tree.clone(),
        Strategy::Greedy => greedy_tree(&ctx, n, opts.training),
        Strategy::Optimal => {
            // Clamp to the DP's hard feasibility ceiling so a raised
            // max_dp_inputs degrades to greedy (like every other over-limit
            // case) instead of erroring inside optimal_tree.
            if n <= opts.max_dp_inputs.min(MAX_DP_INPUTS_HARD) {
                optimal_tree(&ctx, n, opts.training, opts.cost_cap)?
            } else {
                greedy_tree(&ctx, n, opts.training)
            }
        }
    };
    if let Some(cap) = opts.cost_cap {
        if tree_cost(&ctx, &tree, opts.training, Some(cap)).is_none() {
            return Err(format!(
                "no evaluation path satisfies per-step cost cap {}",
                cap
            ));
        }
    }

    build_plan(&ctx, &tree, opts, ltr_cost)
}

/// Parse + size + plan in one call (the Figure 1a `contract_path` API).
pub fn contract_path(expr: &str, dims: &[Vec<usize>], opts: &PlanOptions) -> Result<Plan, String> {
    let spec = parse(expr).map_err(|e| e.to_string())?;
    let sized = SizedSpec::new(spec, dims.to_vec())?;
    plan_with(&sized, opts)
}

// ---------------------------------------------------------------------------
// Contraction trees
// ---------------------------------------------------------------------------

/// A binary contraction tree over input indices, as (left, right) subtree
/// pairs identified by subset masks with a split table.
#[derive(Debug, Clone)]
struct Tree {
    /// For every non-leaf subset mask on the tree: its (left, right) split.
    splits: Vec<(u64, u64, u64)>, // (mask, left, right) in bottom-up order
    root: u64,
}

fn left_to_right_tree(n: usize) -> Tree {
    let mut splits = Vec::new();
    let mut acc = 1u64;
    for i in 1..n {
        let next = acc | (1 << i);
        splits.push((next, acc, 1u64 << i));
        acc = next;
    }
    Tree { splits, root: acc }
}

/// Total cost of a tree; None if any step exceeds `cap`.
fn tree_cost(ctx: &NetCtx, tree: &Tree, training: bool, cap: Option<f64>) -> Option<f64> {
    let mut total = 0.0;
    for &(_, l, r) in &tree.splits {
        let sa = ctx.subset(l);
        let sb = ctx.subset(r);
        let merge = analyze_merge(ctx, &sa, &sb);
        let c = merge.dims.mults(training);
        if let Some(cap) = cap {
            if c > cap {
                return None;
            }
        }
        total += c;
    }
    Some(total)
}

/// Hard ceiling on exact-DP input count: beyond this the `O(2^n)` tables
/// are plainly infeasible, so `plan_with` routes to greedy regardless of
/// `max_dp_inputs`.
const MAX_DP_INPUTS_HARD: usize = 30;

/// Exact subset DP (netcon-equivalent optimum).
fn optimal_tree(
    ctx: &NetCtx,
    n: usize,
    training: bool,
    cap: Option<f64>,
) -> Result<Tree, String> {
    // `plan_with` already rejects n > 63 and clamps DP dispatch to
    // MAX_DP_INPUTS_HARD, but compute the full mask checked rather than
    // special-casing: a u64::MAX full mask would make the `1..=full` scan
    // below never terminate. Keep a defensive error for direct callers.
    if n > MAX_DP_INPUTS_HARD {
        return Err(format!(
            "exact subset DP limited to {MAX_DP_INPUTS_HARD} inputs (got {n}); \
             use Strategy::Greedy or lower max_dp_inputs"
        ));
    }
    let full: u64 = 1u64
        .checked_shl(n as u32)
        .map(|v| v - 1)
        .ok_or_else(|| format!("subset DP mask overflow for {n} inputs"))?;
    let size = 1usize << n;
    let mut best = vec![f64::INFINITY; size];
    let mut split: Vec<(u64, u64)> = vec![(0, 0); size];
    // Cache SubSpecs per mask (they are order-independent).
    let mut subs: Vec<Option<SubSpec>> = vec![None; size];
    for i in 0..n {
        best[1 << i] = 0.0;
        subs[1 << i] = Some(ctx.leaf(i));
    }
    // Iterate masks in increasing order (all submasks precede their mask).
    for mask in 1..=full {
        if mask.count_ones() < 2 {
            continue;
        }
        if subs[mask as usize].is_none() {
            subs[mask as usize] = Some(ctx.subset(mask));
        }
        // Enumerate proper submask splits; dedupe unordered pairs by
        // requiring s to contain the lowest set bit of mask.
        let low = mask & mask.wrapping_neg();
        let mut s = (mask - 1) & mask;
        while s != 0 {
            if s & low != 0 {
                let t = mask ^ s;
                if best[s as usize].is_finite() && best[t as usize].is_finite() {
                    let sa = subs[s as usize].get_or_insert_with(|| ctx.subset(s));
                    let sa = sa.clone();
                    let sb = subs[t as usize].get_or_insert_with(|| ctx.subset(t));
                    let merge = analyze_merge(ctx, &sa, sb);
                    let step = merge.dims.mults(training);
                    let ok = cap.map_or(true, |c| step <= c);
                    if ok {
                        let cand = best[s as usize] + best[t as usize] + step;
                        if cand < best[mask as usize] {
                            best[mask as usize] = cand;
                            split[mask as usize] = (s, t);
                        }
                    }
                }
            }
            s = (s - 1) & mask;
        }
    }
    if !best[full as usize].is_finite() {
        return Err("no feasible evaluation path under the cost cap".to_string());
    }
    // Reconstruct bottom-up split list.
    let mut splits = Vec::new();
    let mut stack = vec![full];
    let mut order = Vec::new();
    while let Some(m) = stack.pop() {
        if m.count_ones() < 2 {
            continue;
        }
        let (l, r) = split[m as usize];
        order.push((m, l, r));
        stack.push(l);
        stack.push(r);
    }
    order.reverse(); // children before parents
    splits.extend(order);
    Ok(Tree { splits, root: full })
}

/// Cheapest-pair-first greedy.
fn greedy_tree(ctx: &NetCtx, n: usize, training: bool) -> Tree {
    let mut pool: Vec<SubSpec> = (0..n).map(|i| ctx.leaf(i)).collect();
    let mut splits = Vec::new();
    while pool.len() > 1 {
        // Scan all pairs, keeping the winning Merge so it is analyzed once
        // per round instead of twice (scan + post-selection recompute).
        let mut best = (f64::INFINITY, f64::INFINITY, 0usize, 1usize);
        let mut best_merge: Option<Merge> = None;
        for i in 0..pool.len() {
            for j in i + 1..pool.len() {
                let merge = analyze_merge(ctx, &pool[i], &pool[j]);
                let c = merge.dims.mults(training);
                let e = merge.result.elems();
                if (c, e) < (best.0, best.1) {
                    best = (c, e, i, j);
                    best_merge = Some(merge);
                }
            }
        }
        let (_, _, i, j) = best;
        let merge = best_merge.expect("pool has at least one pair");
        let (si, sj) = (pool[i].mask, pool[j].mask);
        splits.push((si | sj, si, sj));
        // remove j first (j > i)
        pool.remove(j);
        pool.remove(i);
        pool.push(merge.result);
    }
    Tree {
        splits,
        root: pool[0].mask,
    }
}

// ---------------------------------------------------------------------------
// Plan construction
// ---------------------------------------------------------------------------

fn build_plan(
    ctx: &NetCtx,
    tree: &Tree,
    opts: &PlanOptions,
    ltr_cost: f64,
) -> Result<Plan, String> {
    let sized = ctx.sized;
    let n = sized.spec.n_inputs();
    // Simulate the working list to assign step positions.
    let mut working: Vec<u64> = (0..n).map(|i| 1u64 << i).collect();
    let mut live_elems: Vec<f64> = (0..n).map(|i| ctx.leaf(i).elems()).collect();
    let mut steps = Vec::new();
    let mut total = 0.0;
    let mut largest = 0.0f64;
    let mut peak_mem = live_elems.iter().sum::<f64>();

    for &(_, l, r) in &tree.splits {
        let i = working
            .iter()
            .position(|&m| m == l)
            .ok_or("internal: split child missing from working list")?;
        let j = working
            .iter()
            .position(|&m| m == r)
            .ok_or("internal: split child missing from working list")?;
        let sa = ctx.subset(l);
        let sb = ctx.subset(r);
        let merge = analyze_merge(ctx, &sa, &sb);
        let (step_sized, moduli) = step_sized_spec(ctx, &sa, &sb, &merge);
        let cost = merge.dims.mults(opts.training);
        let out_elems = merge.result.elems();
        total += cost;
        largest = largest.max(out_elems);
        peak_mem = peak_mem.max(live_elems.iter().sum::<f64>() + out_elems);
        steps.push(PlanStep {
            lhs: i,
            rhs: j,
            expr: step_sized.spec.render(),
            sized: step_sized,
            moduli,
            cost,
            out_elems,
        });
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        working.remove(hi);
        working.remove(lo);
        live_elems.remove(hi);
        live_elems.remove(lo);
        working.push(l | r);
        live_elems.push(out_elems);
    }

    // Final permutation: last step output is mode-sorted; map to requested.
    let root_sub = ctx.subset(tree.root);
    let final_perm: Vec<usize> = sized
        .spec
        .output
        .iter()
        .map(|m| {
            root_sub
                .modes
                .iter()
                .position(|x| x == m)
                .ok_or_else(|| format!("output mode missing from root intermediate"))
        })
        .collect::<Result<_, _>>()?;
    let is_identity = final_perm.iter().enumerate().all(|(i, &p)| i == p);

    Ok(Plan {
        expr: sized.spec.render(),
        n_inputs: n,
        strategy: opts.strategy,
        training: opts.training,
        backend: opts.backend,
        steps,
        final_perm: if is_identity { None } else { Some(final_perm) },
        cost: total,
        naive_cost: ltr_cost,
        flat_cost: flat_cost(sized),
        largest_intermediate: largest,
        peak_mem_elems: peak_mem,
    })
}

#[cfg(test)]
mod tests;
