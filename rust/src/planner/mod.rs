//! The optimal sequencer (paper §3.2): decomposes an N-input conv_einsum
//! into a FLOPs-minimal sequence of pairwise operations.
//!
//! netcon [Pfeifer–Haegeman–Verstraete 2014] searches the space of pairwise
//! contraction trees; our extension swaps its contraction-cost function for
//! the tnn-cost model ([`crate::cost`]) which prices convolutions (Eq. 8)
//! and, in training mode, the backward computations `g1`/`g2`.
//!
//! Strategies:
//! * [`Strategy::Optimal`] — exact subset dynamic program (equivalent
//!   optimum to netcon's breadth-first search; `O(3^n)` over input subsets).
//! * [`Strategy::Greedy`] — cheapest-pair-first heuristic, for very large
//!   networks.
//! * [`Strategy::LeftToRight`] — the paper's naive baseline.
//! * [`Strategy::Measured`] — measured-cost selection: the top-k
//!   FLOPs-ranked trees (a k-best extension of the same subset DP) plus
//!   their bit-compatible orientation mirrors are scored against the
//!   persistent tuning cache ([`crate::cost::tuning`]); wall-clock
//!   measurements recorded by calibration (`crate::tune`) override the
//!   analytic ranking, and a context with no measurements degrades to
//!   exactly the analytic choice. Selected plans carry a
//!   [`Plan::tuning_generation`] stamp so `CompiledPlan::verify()`
//!   rejects them once the cache moves on.
//!
//! A [`PlanOptions::cost_cap`] restricts the search to trees whose every
//! step costs at most the cap — the "orange path" of the paper's Figure 2.

mod subspec;

pub use subspec::{analyze_merge, step_sized_spec, Merge, NetCtx, SubSpec};

use crate::cost::{flat_cost, tuning, MergeDims};
use crate::einsum::{parse, ConvKind, SizedSpec};
use crate::exec::Backend;
use crate::util::json::Json;
use crate::util::sci;

/// Default candidate count for `Strategy::Measured` (the bare `"measured"`
/// strategy string).
pub const DEFAULT_MEASURED_TOP_K: usize = 4;

/// Evaluation-order search strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Exact FLOPs-minimal tree (netcon-equivalent subset DP).
    Optimal,
    /// Cheapest-pair-first heuristic.
    Greedy,
    /// Naive left-to-right evaluation — the paper's baseline.
    LeftToRight,
    /// Measured-cost tournament over the `top_k` FLOPs-best trees and
    /// their orientation mirrors, ranked by the tuning cache (analytic
    /// FLOPs on cache miss).
    Measured {
        /// How many FLOPs-ranked trees enter the tournament (≥ 1).
        top_k: usize,
    },
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Strategy::Optimal => f.write_str("optimal"),
            Strategy::Greedy => f.write_str("greedy"),
            Strategy::LeftToRight => f.write_str("left-to-right"),
            Strategy::Measured { top_k } => write!(f, "measured:{top_k}"),
        }
    }
}

/// Structured error for an unrecognized [`Strategy`] string: unknown
/// strategies are rejected, never silently defaulted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseStrategyError {
    /// The rejected input, verbatim.
    pub input: String,
}

impl std::fmt::Display for ParseStrategyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown strategy '{}' (expected optimal | greedy | ltr | left-to-right | measured[:K])",
            self.input
        )
    }
}

impl std::error::Error for ParseStrategyError {}

impl std::str::FromStr for Strategy {
    type Err = ParseStrategyError;

    /// Inverse of `Display` (with the `ltr` shorthand and a bare
    /// `measured` defaulting to [`DEFAULT_MEASURED_TOP_K`]). `measured:K`
    /// requires `K ≥ 1`.
    fn from_str(s: &str) -> Result<Strategy, ParseStrategyError> {
        match s.trim() {
            "optimal" => Ok(Strategy::Optimal),
            "greedy" => Ok(Strategy::Greedy),
            "ltr" | "left-to-right" => Ok(Strategy::LeftToRight),
            "measured" => Ok(Strategy::Measured {
                top_k: DEFAULT_MEASURED_TOP_K,
            }),
            other => match other.strip_prefix("measured:").map(str::parse::<usize>) {
                Some(Ok(top_k)) if top_k >= 1 => Ok(Strategy::Measured { top_k }),
                _ => Err(ParseStrategyError {
                    input: s.to_string(),
                }),
            },
        }
    }
}

/// Options controlling planning.
#[derive(Debug, Clone)]
pub struct PlanOptions {
    pub strategy: Strategy,
    /// Price steps with the training cost `f + g1 + g2` (Appendix B) rather
    /// than forward-only.
    pub training: bool,
    /// Reject any tree containing a step costlier than this (paper Fig. 2).
    pub cost_cap: Option<f64>,
    /// Explicit convolution varieties (parallel to the pipe list); `None`
    /// uses the defaults (Same for 2-input modes, Circular for multi-way).
    pub conv_kinds: Option<Vec<ConvKind>>,
    /// Above this input count, Optimal falls back to Greedy.
    pub max_dp_inputs: usize,
    /// Execution backend recorded on the plan (used by `execute_path` and
    /// the autodiff tape; see [`crate::exec::Backend`]).
    pub backend: Backend,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            strategy: Strategy::Optimal,
            training: false,
            cost_cap: None,
            conv_kinds: None,
            max_dp_inputs: 16,
            backend: Backend::default(),
        }
    }
}

/// One pairwise step of a plan, in opt-einsum working-list semantics:
/// operands `lhs`/`rhs` are removed from the list and the result appended.
#[derive(Debug, Clone)]
pub struct PlanStep {
    pub lhs: usize,
    pub rhs: usize,
    /// The executable 2-input spec for this step.
    pub sized: SizedSpec,
    /// Circular wrap moduli per conv mode of the step.
    pub moduli: Vec<Option<usize>>,
    /// Rendered einsum string of the step (for display / goldens).
    pub expr: String,
    /// Multiplications (under the plan's cost mode).
    pub cost: f64,
    /// Elements of the step output.
    pub out_elems: f64,
}

/// A complete evaluation plan for an N-input conv_einsum.
#[derive(Debug, Clone)]
pub struct Plan {
    pub expr: String,
    pub n_inputs: usize,
    pub strategy: Strategy,
    pub training: bool,
    /// Execution backend the plan was made for (overridable at execution
    /// time via `execute_path_with`).
    pub backend: Backend,
    pub steps: Vec<PlanStep>,
    /// Permutation from the last step's (mode-sorted) output to the
    /// requested output order.
    pub final_perm: Option<Vec<usize>>,
    /// Total cost of this plan (multiplications).
    pub cost: f64,
    /// Cost of the naive left-to-right baseline, for the report.
    pub naive_cost: f64,
    /// Single-nested-loop cost (opt-einsum's "naive FLOP count").
    pub flat_cost: f64,
    /// Largest intermediate produced, in elements.
    pub largest_intermediate: f64,
    /// Peak simultaneously-live elements during forward execution
    /// (inputs + working list + current output).
    pub peak_mem_elems: f64,
    /// For measured-strategy plans: the [`crate::cost::tuning`] generation
    /// the selection was scored under. `CompiledPlan::verify()` rejects
    /// the plan once the global cache's generation moves past it (the
    /// measurements it was ranked by are stale). `None` for analytic
    /// strategies, which never depend on cache contents.
    pub tuning_generation: Option<u64>,
}

impl Plan {
    /// Speedup of this plan over left-to-right.
    pub fn speedup_vs_naive(&self) -> f64 {
        self.naive_cost / self.cost.max(1.0)
    }

    /// Orientation-sensitive identity of the evaluation order: one entry
    /// per step carrying the working-list operand positions and the
    /// step's rendered 2-input expression (which distinguishes mirrored
    /// lhs/rhs orders). This is the measurement key inside a tuning-cache
    /// context — stable across processes for a fixed expression + dims.
    pub fn signature(&self) -> String {
        let mut s = String::new();
        for st in &self.steps {
            s.push_str(&format!("{}x{}:{};", st.lhs, st.rhs, st.expr));
        }
        s
    }

    /// Paper-Figure-1b-style report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("  Complete sequence:  {}\n", self.expr));
        s.push_str(&format!("  Naive FLOP count:  {}\n", sci(self.naive_cost)));
        s.push_str(&format!("  Optimized FLOP count:  {}\n", sci(self.cost)));
        s.push_str(&format!(
            "  Largest intermediate:  {} elements\n",
            sci(self.largest_intermediate)
        ));
        s.push_str(&format!("  Strategy: {}", self.strategy));
        if self.training {
            s.push_str("  (training cost model: f + g1 + g2)");
        }
        s.push('\n');
        s.push_str("--------------------------------------------------\n");
        s.push_str("current\n");
        s.push_str("--------------------------------------------------\n");
        for step in &self.steps {
            s.push_str(&format!(
                "{:<40} cost {:>10}  out {:>10}\n",
                step.expr,
                sci(step.cost),
                sci(step.out_elems)
            ));
        }
        s
    }

    /// JSON form (used by golden tests against the python planner mirror).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("expr", Json::str(&self.expr)),
            ("strategy", Json::str(format!("{}", self.strategy))),
            ("training", Json::Bool(self.training)),
            ("cost", Json::num(self.cost)),
            ("naive_cost", Json::num(self.naive_cost)),
            ("flat_cost", Json::num(self.flat_cost)),
            ("largest_intermediate", Json::num(self.largest_intermediate)),
            ("peak_mem_elems", Json::num(self.peak_mem_elems)),
            (
                "steps",
                Json::arr(self.steps.iter().map(|st| {
                    Json::obj(vec![
                        ("lhs", Json::num(st.lhs as f64)),
                        ("rhs", Json::num(st.rhs as f64)),
                        ("expr", Json::str(&st.expr)),
                        ("cost", Json::num(st.cost)),
                        ("out_elems", Json::num(st.out_elems)),
                    ])
                })),
            ),
        ])
    }
}

/// Plan a parsed + sized expression.
pub fn plan_with(sized: &SizedSpec, opts: &PlanOptions) -> Result<Plan, String> {
    let n = sized.spec.n_inputs();
    if n < 2 {
        return Err("planning requires at least 2 inputs".to_string());
    }
    if n > 63 {
        return Err(format!("too many inputs ({n} > 63)"));
    }
    if let Strategy::Measured { top_k } = opts.strategy {
        return measured_plan(sized, opts, top_k);
    }
    // Re-bind conv kinds if the options override them.
    let owned;
    let sized = match &opts.conv_kinds {
        Some(kinds) => {
            owned = SizedSpec::with_kinds(sized.spec.clone(), sized.dims.clone(), kinds.clone())?;
            &owned
        }
        None => sized,
    };
    let ctx = NetCtx::new(sized);

    // The left-to-right baseline is always computed for the report.
    let ltr_tree = left_to_right_tree(n);
    let ltr_cost = tree_cost(&ctx, &ltr_tree, opts.training, None)
        .ok_or("internal: LTR tree must be feasible")?;

    let tree = match opts.strategy {
        Strategy::Measured { .. } => unreachable!("measured planning dispatched above"),
        Strategy::LeftToRight => ltr_tree.clone(),
        Strategy::Greedy => greedy_tree(&ctx, n, opts.training),
        Strategy::Optimal => {
            // Clamp to the DP's hard feasibility ceiling so a raised
            // max_dp_inputs degrades to greedy (like every other over-limit
            // case) instead of erroring inside optimal_tree.
            if n <= opts.max_dp_inputs.min(MAX_DP_INPUTS_HARD) {
                optimal_tree(&ctx, n, opts.training, opts.cost_cap)?
            } else {
                greedy_tree(&ctx, n, opts.training)
            }
        }
    };
    if let Some(cap) = opts.cost_cap {
        if tree_cost(&ctx, &tree, opts.training, Some(cap)).is_none() {
            return Err(format!(
                "no evaluation path satisfies per-step cost cap {}",
                cap
            ));
        }
    }

    build_plan(&ctx, &tree, opts, ltr_cost)
}

/// Parse + size + plan in one call (the Figure 1a `contract_path` API).
pub fn contract_path(expr: &str, dims: &[Vec<usize>], opts: &PlanOptions) -> Result<Plan, String> {
    let spec = parse(expr).map_err(|e| e.to_string())?;
    let sized = SizedSpec::new(spec, dims.to_vec())?;
    plan_with(&sized, opts)
}

// ---------------------------------------------------------------------------
// Contraction trees
// ---------------------------------------------------------------------------

/// A binary contraction tree over input indices, as (left, right) subtree
/// pairs identified by subset masks with a split table.
#[derive(Debug, Clone)]
struct Tree {
    /// For every non-leaf subset mask on the tree: its (left, right) split.
    splits: Vec<(u64, u64, u64)>, // (mask, left, right) in bottom-up order
    root: u64,
}

fn left_to_right_tree(n: usize) -> Tree {
    let mut splits = Vec::new();
    let mut acc = 1u64;
    for i in 1..n {
        let next = acc | (1 << i);
        splits.push((next, acc, 1u64 << i));
        acc = next;
    }
    Tree { splits, root: acc }
}

/// Total cost of a tree; None if any step exceeds `cap`.
fn tree_cost(ctx: &NetCtx, tree: &Tree, training: bool, cap: Option<f64>) -> Option<f64> {
    let mut total = 0.0;
    for &(_, l, r) in &tree.splits {
        let sa = ctx.subset(l);
        let sb = ctx.subset(r);
        let merge = analyze_merge(ctx, &sa, &sb);
        let c = merge.dims.mults(training);
        if let Some(cap) = cap {
            if c > cap {
                return None;
            }
        }
        total += c;
    }
    Some(total)
}

/// Hard ceiling on exact-DP input count: beyond this the `O(2^n)` tables
/// are plainly infeasible, so `plan_with` routes to greedy regardless of
/// `max_dp_inputs`.
const MAX_DP_INPUTS_HARD: usize = 30;

/// Exact subset DP (netcon-equivalent optimum).
fn optimal_tree(
    ctx: &NetCtx,
    n: usize,
    training: bool,
    cap: Option<f64>,
) -> Result<Tree, String> {
    // `plan_with` already rejects n > 63 and clamps DP dispatch to
    // MAX_DP_INPUTS_HARD, but compute the full mask checked rather than
    // special-casing: a u64::MAX full mask would make the `1..=full` scan
    // below never terminate. Keep a defensive error for direct callers.
    if n > MAX_DP_INPUTS_HARD {
        return Err(format!(
            "exact subset DP limited to {MAX_DP_INPUTS_HARD} inputs (got {n}); \
             use Strategy::Greedy or lower max_dp_inputs"
        ));
    }
    let full: u64 = 1u64
        .checked_shl(n as u32)
        .map(|v| v - 1)
        .ok_or_else(|| format!("subset DP mask overflow for {n} inputs"))?;
    let size = 1usize << n;
    let mut best = vec![f64::INFINITY; size];
    let mut split: Vec<(u64, u64)> = vec![(0, 0); size];
    // Cache SubSpecs per mask (they are order-independent).
    let mut subs: Vec<Option<SubSpec>> = vec![None; size];
    for i in 0..n {
        best[1 << i] = 0.0;
        subs[1 << i] = Some(ctx.leaf(i));
    }
    // Iterate masks in increasing order (all submasks precede their mask).
    for mask in 1..=full {
        if mask.count_ones() < 2 {
            continue;
        }
        if subs[mask as usize].is_none() {
            subs[mask as usize] = Some(ctx.subset(mask));
        }
        // Enumerate proper submask splits; dedupe unordered pairs by
        // requiring s to contain the lowest set bit of mask.
        let low = mask & mask.wrapping_neg();
        let mut s = (mask - 1) & mask;
        while s != 0 {
            if s & low != 0 {
                let t = mask ^ s;
                if best[s as usize].is_finite() && best[t as usize].is_finite() {
                    let sa = subs[s as usize].get_or_insert_with(|| ctx.subset(s));
                    let sa = sa.clone();
                    let sb = subs[t as usize].get_or_insert_with(|| ctx.subset(t));
                    let merge = analyze_merge(ctx, &sa, sb);
                    let step = merge.dims.mults(training);
                    let ok = cap.map_or(true, |c| step <= c);
                    if ok {
                        let cand = best[s as usize] + best[t as usize] + step;
                        if cand < best[mask as usize] {
                            best[mask as usize] = cand;
                            split[mask as usize] = (s, t);
                        }
                    }
                }
            }
            s = (s - 1) & mask;
        }
    }
    if !best[full as usize].is_finite() {
        return Err("no feasible evaluation path under the cost cap".to_string());
    }
    // Reconstruct bottom-up split list.
    let mut splits = Vec::new();
    let mut stack = vec![full];
    let mut order = Vec::new();
    while let Some(m) = stack.pop() {
        if m.count_ones() < 2 {
            continue;
        }
        let (l, r) = split[m as usize];
        order.push((m, l, r));
        stack.push(l);
        stack.push(r);
    }
    order.reverse(); // children before parents
    splits.extend(order);
    Ok(Tree { splits, root: full })
}

/// Cheapest-pair-first greedy.
fn greedy_tree(ctx: &NetCtx, n: usize, training: bool) -> Tree {
    let mut pool: Vec<SubSpec> = (0..n).map(|i| ctx.leaf(i)).collect();
    let mut splits = Vec::new();
    while pool.len() > 1 {
        // Scan all pairs, keeping the winning Merge so it is analyzed once
        // per round instead of twice (scan + post-selection recompute).
        let mut best = (f64::INFINITY, f64::INFINITY, 0usize, 1usize);
        let mut best_merge: Option<Merge> = None;
        for i in 0..pool.len() {
            for j in i + 1..pool.len() {
                let merge = analyze_merge(ctx, &pool[i], &pool[j]);
                let c = merge.dims.mults(training);
                let e = merge.result.elems();
                if (c, e) < (best.0, best.1) {
                    best = (c, e, i, j);
                    best_merge = Some(merge);
                }
            }
        }
        let (_, _, i, j) = best;
        let merge = best_merge.expect("pool has at least one pair");
        let (si, sj) = (pool[i].mask, pool[j].mask);
        splits.push((si | sj, si, sj));
        // remove j first (j > i)
        pool.remove(j);
        pool.remove(i);
        pool.push(merge.result);
    }
    Tree {
        splits,
        root: pool[0].mask,
    }
}

// ---------------------------------------------------------------------------
// Measured-cost planning (Strategy::Measured)
// ---------------------------------------------------------------------------

/// One entry of the k-best DP: a candidate tree for a subset, as the cost
/// plus the split and the indices of the child entries it composes.
#[derive(Debug, Clone, Copy)]
struct KbEntry {
    cost: f64,
    l: u64,
    r: u64,
    li: u32,
    ri: u32,
}

/// k-best extension of [`optimal_tree`]: per subset mask, keep the `k`
/// cheapest candidate trees instead of one. Entries compose child entries
/// by index, so every kept entry reconstructs a distinct tree (the
/// orientation dedupe of the base DP carries over: a split and its swap
/// are never both enumerated). Returned cost-ascending; index 0 is the
/// FLOPs-optimal tree of [`optimal_tree`].
fn k_best_trees(
    ctx: &NetCtx,
    n: usize,
    training: bool,
    cap: Option<f64>,
    k: usize,
) -> Result<Vec<Tree>, String> {
    if n > MAX_DP_INPUTS_HARD {
        return Err(format!(
            "exact subset DP limited to {MAX_DP_INPUTS_HARD} inputs (got {n}); \
             use Strategy::Greedy or lower max_dp_inputs"
        ));
    }
    let k = k.max(1);
    let full: u64 = 1u64
        .checked_shl(n as u32)
        .map(|v| v - 1)
        .ok_or_else(|| format!("subset DP mask overflow for {n} inputs"))?;
    let size = 1usize << n;
    let mut entries: Vec<Vec<KbEntry>> = vec![Vec::new(); size];
    let mut subs: Vec<Option<SubSpec>> = vec![None; size];
    for i in 0..n {
        entries[1 << i].push(KbEntry {
            cost: 0.0,
            l: 0,
            r: 0,
            li: 0,
            ri: 0,
        });
        subs[1 << i] = Some(ctx.leaf(i));
    }
    for mask in 1..=full {
        if mask.count_ones() < 2 {
            continue;
        }
        if subs[mask as usize].is_none() {
            subs[mask as usize] = Some(ctx.subset(mask));
        }
        let low = mask & mask.wrapping_neg();
        let mut cands: Vec<KbEntry> = Vec::new();
        let mut s = (mask - 1) & mask;
        while s != 0 {
            if s & low != 0 {
                let t = mask ^ s;
                if !entries[s as usize].is_empty() && !entries[t as usize].is_empty() {
                    let sa = subs[s as usize].get_or_insert_with(|| ctx.subset(s));
                    let sa = sa.clone();
                    let sb = subs[t as usize].get_or_insert_with(|| ctx.subset(t));
                    let merge = analyze_merge(ctx, &sa, sb);
                    let step = merge.dims.mults(training);
                    if cap.map_or(true, |c| step <= c) {
                        for (li, el) in entries[s as usize].iter().enumerate() {
                            for (ri, er) in entries[t as usize].iter().enumerate() {
                                cands.push(KbEntry {
                                    cost: el.cost + er.cost + step,
                                    l: s,
                                    r: t,
                                    li: li as u32,
                                    ri: ri as u32,
                                });
                            }
                        }
                    }
                }
            }
            s = (s - 1) & mask;
        }
        cands.sort_by(|a, b| a.cost.total_cmp(&b.cost));
        cands.truncate(k);
        entries[mask as usize] = cands;
    }
    if entries[full as usize].is_empty() {
        return Err("no feasible evaluation path under the cost cap".to_string());
    }
    let mut trees = Vec::with_capacity(entries[full as usize].len());
    for i in 0..entries[full as usize].len() {
        let mut splits = Vec::new();
        kb_collect(&entries, full, i, &mut splits);
        trees.push(Tree { splits, root: full });
    }
    Ok(trees)
}

/// Reconstruct entry `idx` of `mask` into a bottom-up split list
/// (children before parents, matching [`optimal_tree`]'s output shape).
fn kb_collect(entries: &[Vec<KbEntry>], mask: u64, idx: usize, splits: &mut Vec<(u64, u64, u64)>) {
    if mask.count_ones() < 2 {
        return;
    }
    let e = entries[mask as usize][idx];
    kb_collect(entries, e.l, e.li as usize, splits);
    kb_collect(entries, e.r, e.ri as usize, splits);
    splits.push((mask, e.l, e.r));
}

/// Whether swapping lhs/rhs of a contraction step preserves result bits
/// under the currently selected kernel table.
///
/// A mirrored step computes `dot(b_row, a_row)` where the original
/// computes `dot(a_row, b_row)` — bit-identical, since multiplication
/// commutes and the accumulation order over the contracted index is the
/// same. The one thing a swap *can* change is kernel-path routing: the
/// packed-GEMM engagement predicate is orientation-sensitive, and the
/// packed path accumulates in a different (pure-FMA-chain) order than
/// the unblocked loops. So a swap is safe iff both orientations route
/// identically on the forward *and* both backward geometries, under each
/// orientation's own resolved (possibly per-geometry-tuned) parameters.
/// Conv steps are never mirrored: the conv triple tables and `Same`
/// output extents are asymmetric in the operands.
fn mirror_safe(dims: &MergeDims) -> bool {
    if !dims.conv.is_empty() {
        return false;
    }
    let (t, n, s) = (dims.t as usize, dims.n as usize, dims.s as usize);
    if s < crate::kernels::LANES {
        return true; // tiny-depth scalar path in both orientations
    }
    let table = crate::kernels::dispatch::selected();
    let fwd = crate::kernels::dispatch::resolved_gemm(table, t, n, s);
    let mir = crate::kernels::dispatch::resolved_gemm(table, n, t, s);
    match (fwd, mir) {
        (None, None) => true, // no packed path: unblocked loops both ways
        (Some(a), Some(b)) => {
            // forward out = A·Bᵀ vs mirrored out = B·Aᵀ
            a.engages(t, n, s) == b.engages(n, t, s)
                // gradient wrt A: original da-branch vs mirrored db-branch
                && a.engages(t, s, n) == b.engages(t, s, n)
                // gradient wrt B: original db-branch vs mirrored da-branch
                && a.engages(n, s, t) == b.engages(n, s, t)
        }
        _ => false,
    }
}

/// The orientation mirror of `tree`: every bit-compatible contraction
/// split swapped `(l, r) → (r, l)`. Mirrors have identical analytic cost
/// and bit-identical outputs/gradients, but different wall-clock: the
/// parallel backend partitions work over output rows (`g·t` rows of
/// length `n` vs `g·n` rows of length `t`), so task granularity — and
/// pool utilization — differs per orientation. `None` when no split is
/// eligible (nothing to measure).
fn mirrored_tree(ctx: &NetCtx, tree: &Tree) -> Option<Tree> {
    let mut swapped_any = false;
    let mut splits = Vec::with_capacity(tree.splits.len());
    for &(mask, l, r) in &tree.splits {
        let sa = ctx.subset(l);
        let sb = ctx.subset(r);
        let merge = analyze_merge(ctx, &sa, &sb);
        if mirror_safe(&merge.dims) {
            splits.push((mask, r, l));
            swapped_any = true;
        } else {
            splits.push((mask, l, r));
        }
    }
    swapped_any.then_some(Tree {
        splits,
        root: tree.root,
    })
}

/// The candidate set `Strategy::Measured` scores: the top-k FLOPs-ranked
/// trees (k-best subset DP; greedy above the DP input limit), each
/// followed by its bit-compatible orientation mirror when one exists.
/// Ordered FLOPs-ascending with the canonical FLOPs-best tree first —
/// [`crate::cost::tuning::select_index`]'s first-wins tie-break therefore
/// reproduces the analytic choice when measurements don't disagree.
///
/// Public so calibration (`crate::tune`) enumerates exactly the set the
/// planner will later rank.
pub fn candidate_plans(
    sized: &SizedSpec,
    opts: &PlanOptions,
    top_k: usize,
) -> Result<Vec<Plan>, String> {
    let n = sized.spec.n_inputs();
    if n < 2 {
        return Err("planning requires at least 2 inputs".to_string());
    }
    if n > 63 {
        return Err(format!("too many inputs ({n} > 63)"));
    }
    let owned;
    let sized = match &opts.conv_kinds {
        Some(kinds) => {
            owned = SizedSpec::with_kinds(sized.spec.clone(), sized.dims.clone(), kinds.clone())?;
            &owned
        }
        None => sized,
    };
    let ctx = NetCtx::new(sized);
    let ltr_tree = left_to_right_tree(n);
    let ltr_cost = tree_cost(&ctx, &ltr_tree, opts.training, None)
        .ok_or("internal: LTR tree must be feasible")?;

    let base = if n <= opts.max_dp_inputs.min(MAX_DP_INPUTS_HARD) {
        k_best_trees(&ctx, n, opts.training, opts.cost_cap, top_k)?
    } else {
        vec![greedy_tree(&ctx, n, opts.training)]
    };

    let mut plans = Vec::with_capacity(base.len() * 2);
    for tree in &base {
        if tree_cost(&ctx, tree, opts.training, opts.cost_cap).is_none() {
            continue; // greedy fallback may violate the cap
        }
        plans.push(build_plan(&ctx, tree, opts, ltr_cost)?);
        if let Some(mirror) = mirrored_tree(&ctx, tree) {
            plans.push(build_plan(&ctx, &mirror, opts, ltr_cost)?);
        }
    }
    if plans.is_empty() {
        return Err(format!(
            "no evaluation path satisfies per-step cost cap {:?}",
            opts.cost_cap
        ));
    }
    Ok(plans)
}

/// Measured-cost plan selection: rank [`candidate_plans`] by the global
/// tuning cache's measurements for this execution context, falling back
/// to analytic FLOPs when the context is unmeasured, and stamp the chosen
/// plan with the current tuning generation.
fn measured_plan(sized: &SizedSpec, opts: &PlanOptions, top_k: usize) -> Result<Plan, String> {
    let mut cands = candidate_plans(sized, opts, top_k)?;
    let key = tuning::CalibKey::current(&cands[0].expr, &sized.dims, opts.backend, opts.training);
    let measured = tuning::global().measurements(&key.context_id());
    let scored: Vec<(String, f64)> = cands.iter().map(|p| (p.signature(), p.cost)).collect();
    let scores = tuning::blend_scores(&scored, &measured, opts.training);
    let idx = tuning::select_index(&scores);
    let mut plan = cands.swap_remove(idx);
    plan.tuning_generation = Some(tuning::generation());
    Ok(plan)
}

// ---------------------------------------------------------------------------
// Plan construction
// ---------------------------------------------------------------------------

fn build_plan(
    ctx: &NetCtx,
    tree: &Tree,
    opts: &PlanOptions,
    ltr_cost: f64,
) -> Result<Plan, String> {
    let sized = ctx.sized;
    let n = sized.spec.n_inputs();
    // Simulate the working list to assign step positions.
    let mut working: Vec<u64> = (0..n).map(|i| 1u64 << i).collect();
    let mut live_elems: Vec<f64> = (0..n).map(|i| ctx.leaf(i).elems()).collect();
    let mut steps = Vec::new();
    let mut total = 0.0;
    let mut largest = 0.0f64;
    let mut peak_mem = live_elems.iter().sum::<f64>();

    for &(_, l, r) in &tree.splits {
        let i = working
            .iter()
            .position(|&m| m == l)
            .ok_or("internal: split child missing from working list")?;
        let j = working
            .iter()
            .position(|&m| m == r)
            .ok_or("internal: split child missing from working list")?;
        let sa = ctx.subset(l);
        let sb = ctx.subset(r);
        let merge = analyze_merge(ctx, &sa, &sb);
        let (step_sized, moduli) = step_sized_spec(ctx, &sa, &sb, &merge);
        let cost = merge.dims.mults(opts.training);
        let out_elems = merge.result.elems();
        total += cost;
        largest = largest.max(out_elems);
        peak_mem = peak_mem.max(live_elems.iter().sum::<f64>() + out_elems);
        steps.push(PlanStep {
            lhs: i,
            rhs: j,
            expr: step_sized.spec.render(),
            sized: step_sized,
            moduli,
            cost,
            out_elems,
        });
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        working.remove(hi);
        working.remove(lo);
        live_elems.remove(hi);
        live_elems.remove(lo);
        working.push(l | r);
        live_elems.push(out_elems);
    }

    // Final permutation: last step output is mode-sorted; map to requested.
    let root_sub = ctx.subset(tree.root);
    let final_perm: Vec<usize> = sized
        .spec
        .output
        .iter()
        .map(|m| {
            root_sub
                .modes
                .iter()
                .position(|x| x == m)
                .ok_or_else(|| format!("output mode missing from root intermediate"))
        })
        .collect::<Result<_, _>>()?;
    let is_identity = final_perm.iter().enumerate().all(|(i, &p)| i == p);

    Ok(Plan {
        expr: sized.spec.render(),
        n_inputs: n,
        strategy: opts.strategy,
        training: opts.training,
        backend: opts.backend,
        steps,
        final_perm: if is_identity { None } else { Some(final_perm) },
        cost: total,
        naive_cost: ltr_cost,
        flat_cost: flat_cost(sized),
        largest_intermediate: largest,
        peak_mem_elems: peak_mem,
        tuning_generation: None,
    })
}

#[cfg(test)]
mod tests;
