//! Subexpression bookkeeping for the optimal sequencer.
//!
//! A [`SubSpec`] describes the intermediate tensor obtained by fully merging
//! a *subset* (bitmask) of the expression's inputs. Crucially its shape is
//! **order-independent** — circular convolution support grows as
//! `min(Σ sizes − (k−1), P)` and all other mode sizes are fixed — which is
//! what makes netcon-style dynamic programming over subsets sound in the
//! presence of convolutions.

use crate::cost::{conv_out_size, MergeDims};
use crate::einsum::{ConvKind, ModeId, SizedSpec};

/// Global, per-expression context shared by all subsets.
pub struct NetCtx<'a> {
    pub sized: &'a SizedSpec,
    /// For every mode: bitmask of inputs containing it.
    pub occ_mask: Vec<u64>,
    /// For conv modes (indexed by pipe position): global feature size =
    /// wrap modulus for circular steps.
    pub conv_feature: Vec<usize>,
    /// Convolution variety per pipe position.
    pub conv_kinds: Vec<ConvKind>,
    /// Set of output modes.
    pub out_set: Vec<bool>,
}

impl<'a> NetCtx<'a> {
    pub fn new(sized: &'a SizedSpec) -> NetCtx<'a> {
        let n_modes = sized.spec.modes.len();
        let mut occ_mask = vec![0u64; n_modes];
        for (i, modes) in sized.spec.inputs.iter().enumerate() {
            for &m in modes {
                occ_mask[m as usize] |= 1 << i;
            }
        }
        let conv_feature = sized
            .spec
            .conv
            .iter()
            .map(|&m| sized.conv_feature_size(m))
            .collect();
        let mut out_set = vec![false; n_modes];
        for &m in &sized.spec.output {
            out_set[m as usize] = true;
        }
        NetCtx {
            sized,
            occ_mask,
            conv_feature,
            conv_kinds: sized.conv_kinds.clone(),
            out_set,
        }
    }

    /// Pipe position of conv mode `m` (None if not a conv mode).
    pub fn conv_pos(&self, m: ModeId) -> Option<usize> {
        self.sized.spec.conv.iter().position(|&x| x == m)
    }

    /// Is mode `m` needed outside subset `mask` (in the output or in inputs
    /// not yet merged)?
    pub fn needed_outside(&self, m: ModeId, mask: u64) -> bool {
        self.out_set[m as usize] || (self.occ_mask[m as usize] & !mask) != 0
    }

    /// The [`SubSpec`] of a single input.
    pub fn leaf(&self, i: usize) -> SubSpec {
        SubSpec {
            mask: 1 << i,
            modes: self.sized.spec.inputs[i].clone(),
            sizes: self.sized.dims[i].clone(),
        }
    }

    /// The [`SubSpec`] for an arbitrary subset, built directly (used for
    /// testing the order-independence invariant and by the greedy search).
    ///
    /// Singleton subsets return the *leaf* spec (original mode order,
    /// self-sum modes still present — they are only summed when the input
    /// first participates in a merge, matching the executed tensors).
    pub fn subset(&self, mask: u64) -> SubSpec {
        if mask.count_ones() == 1 {
            return self.leaf(mask.trailing_zeros() as usize);
        }
        let spec = &self.sized.spec;
        let mut modes: Vec<ModeId> = Vec::new();
        for m in spec.all_modes() {
            let occ = self.occ_mask[m as usize];
            if occ & mask == 0 {
                continue; // not present in this subset
            }
            if self.needed_outside(m, mask) {
                modes.push(m);
            } else if spec.is_conv(m) {
                modes.push(m); // conv modes are always in the output
            }
        }
        modes.sort_unstable();
        let sizes = modes.iter().map(|&m| self.mode_size_in(m, mask)).collect();
        SubSpec { mask, modes, sizes }
    }

    /// Size of mode `m` within the intermediate for subset `mask`.
    pub fn mode_size_in(&self, m: ModeId, mask: u64) -> usize {
        let spec = &self.sized.spec;
        if !spec.is_conv(m) {
            return self.sized.mode_size(m);
        }
        // Gather the occurrence sizes inside the subset.
        let mut inside: Vec<usize> = Vec::new();
        for (i, modes) in spec.inputs.iter().enumerate() {
            if mask & (1 << i) == 0 {
                continue;
            }
            if let Some(pos) = modes.iter().position(|&x| x == m) {
                inside.push(self.sized.dims[i][pos]);
            }
        }
        let pipe = self.conv_pos(m).unwrap();
        match inside.len() {
            0 => unreachable!(),
            1 => inside[0],
            k => {
                let kind = self.conv_kinds[pipe];
                match kind {
                    ConvKind::Circular => {
                        let p = self.conv_feature[pipe];
                        (inside.iter().sum::<usize>() - (k - 1)).min(p)
                    }
                    // Non-circular varieties only permit 2 occurrences
                    // (validated in SizedSpec), both inside here:
                    _ => kind.out_dim(inside[0], inside[1]),
                }
            }
        }
    }
}

/// The intermediate tensor for a subset of inputs: its modes (sorted by id)
/// and sizes.
#[derive(Debug, Clone, PartialEq)]
pub struct SubSpec {
    pub mask: u64,
    pub modes: Vec<ModeId>,
    pub sizes: Vec<usize>,
}

impl SubSpec {
    pub fn elems(&self) -> f64 {
        self.sizes.iter().map(|&s| s as f64).product()
    }

    pub fn size_of(&self, m: ModeId) -> Option<usize> {
        self.modes
            .iter()
            .position(|&x| x == m)
            .map(|p| self.sizes[p])
    }
}

/// Everything about merging two disjoint subexpressions.
pub struct Merge {
    pub dims: MergeDims,
    pub result: SubSpec,
}

/// Analyze the pairwise merge of `a` and `b` under context `ctx`.
pub fn analyze_merge(ctx: &NetCtx, a: &SubSpec, b: &SubSpec) -> Merge {
    debug_assert_eq!(a.mask & b.mask, 0, "subsets must be disjoint");
    let spec = &ctx.sized.spec;
    let union = a.mask | b.mask;

    let mut dims = MergeDims {
        g: 1.0,
        t: 1.0,
        n: 1.0,
        s: 1.0,
        presum: 1.0,
        conv: Vec::new(),
    };
    let mut out_modes: Vec<ModeId> = Vec::new();
    let mut out_sizes: Vec<usize> = Vec::new();

    let mut all: Vec<ModeId> = a.modes.iter().chain(b.modes.iter()).copied().collect();
    all.sort_unstable();
    all.dedup();

    for &m in &all {
        let sa = a.size_of(m);
        let sb = b.size_of(m);
        let needed = ctx.needed_outside(m, union);
        let is_conv = spec.is_conv(m);
        match (sa, sb) {
            (Some(ia), Some(ib)) if is_conv => {
                let pipe = ctx.conv_pos(m).unwrap();
                let kind = ctx.conv_kinds[pipe];
                let modulus = match kind {
                    ConvKind::Circular => Some(ctx.conv_feature[pipe]),
                    _ => None,
                };
                let io = conv_out_size(kind, ia, ib, modulus);
                dims.conv.push((ia as f64, ib as f64, io as f64));
                out_modes.push(m);
                out_sizes.push(io);
            }
            (Some(ia), Some(_)) => {
                if needed {
                    dims.g *= ia as f64;
                    out_modes.push(m);
                    out_sizes.push(ia);
                } else {
                    dims.s *= ia as f64;
                }
            }
            (Some(ia), None) => {
                if needed || is_conv {
                    dims.t *= ia as f64;
                    out_modes.push(m);
                    out_sizes.push(ia);
                } else {
                    dims.presum *= ia as f64;
                }
            }
            (None, Some(ib)) => {
                if needed || is_conv {
                    dims.n *= ib as f64;
                    out_modes.push(m);
                    out_sizes.push(ib);
                } else {
                    dims.presum *= ib as f64;
                }
            }
            (None, None) => unreachable!(),
        }
    }

    Merge {
        dims,
        result: SubSpec {
            mask: union,
            modes: out_modes,
            sizes: out_sizes,
        },
    }
}

/// Build the executable 2-input [`SizedSpec`] (plus wrap moduli) for a merge
/// step. The step's output mode order is the merged SubSpec's (sorted) mode
/// order; `override_output` substitutes a caller-chosen order for the final
/// step.
pub fn step_sized_spec(
    ctx: &NetCtx,
    a: &SubSpec,
    b: &SubSpec,
    merge: &Merge,
) -> (SizedSpec, Vec<Option<usize>>) {
    let spec = &ctx.sized.spec;
    // Construct a fresh EinsumSpec reusing the parent's mode table.
    let mut conv_modes: Vec<ModeId> = Vec::new();
    for &m in merge
        .result
        .modes
        .iter()
        .chain(a.modes.iter())
        .chain(b.modes.iter())
    {
        if spec.is_conv(m) && !conv_modes.contains(&m) {
            conv_modes.push(m);
        }
    }
    conv_modes.sort_unstable_by_key(|m| ctx.conv_pos(*m).unwrap());

    let step_spec = crate::einsum::EinsumSpec {
        modes: spec.modes.clone(),
        inputs: vec![a.modes.clone(), b.modes.clone()],
        output: merge.result.modes.clone(),
        conv: conv_modes.clone(),
    };
    let kinds: Vec<ConvKind> = conv_modes
        .iter()
        .map(|&m| ctx.conv_kinds[ctx.conv_pos(m).unwrap()])
        .collect();
    let moduli: Vec<Option<usize>> = conv_modes
        .iter()
        .map(|&m| {
            let pipe = ctx.conv_pos(m).unwrap();
            match ctx.conv_kinds[pipe] {
                ConvKind::Circular => Some(ctx.conv_feature[pipe]),
                _ => None,
            }
        })
        .collect();
    let sized = SizedSpec::with_kinds(
        step_spec,
        vec![a.sizes.clone(), b.sizes.clone()],
        kinds,
    )
    .expect("internal: step spec must validate");
    (sized, moduli)
}
