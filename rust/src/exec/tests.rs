//! Tests for the pairwise executor, path executor and the high-level
//! `conv_einsum` entry point. The oracle is the brute-force reference
//! evaluator; property tests sweep random shapes and mode structures.

use super::*;
use crate::einsum::{parse, ConvKind, SizedSpec};
use crate::planner::PlanOptions;
use crate::tensor::Tensor;
use crate::util::prop;
use crate::util::rng::Rng;

fn sized(expr: &str, dims: Vec<Vec<usize>>) -> SizedSpec {
    SizedSpec::new(parse(expr).unwrap(), dims).unwrap()
}

fn rand_inputs(sized: &SizedSpec, rng: &mut Rng) -> Vec<Tensor> {
    sized
        .dims
        .iter()
        .map(|d| Tensor::rand(d, -1.0, 1.0, rng))
        .collect()
}

fn check_pairwise(expr: &str, dims: Vec<Vec<usize>>, seed: u64) {
    let s = sized(expr, dims);
    let mut rng = Rng::new(seed);
    let inputs = rand_inputs(&s, &mut rng);
    let got = pairwise(&s, &inputs[0], &inputs[1]);
    let want = naive_eval(&s, &[&inputs[0], &inputs[1]]);
    got.assert_close(&want, 1e-3);
}

#[test]
fn matmul_matches_reference() {
    check_pairwise("ij,jk->ik", vec![vec![3, 4], vec![4, 5]], 1);
}

#[test]
fn batch_matmul_matches_reference() {
    check_pairwise("bij,bjk->bik", vec![vec![2, 3, 4], vec![2, 4, 5]], 2);
}

#[test]
fn outer_product_matches_reference() {
    check_pairwise("ab,cd->abcd", vec![vec![2, 3], vec![4, 5]], 3);
}

#[test]
fn paper_section21_example() {
    // T_{b,i,j} = Σ_c T1_{b,c,i} T2_{b,c,j}
    check_pairwise("bci,bcj->bij", vec![vec![2, 3, 4], vec![2, 3, 5]], 4);
}

#[test]
fn selfsum_matches_reference() {
    check_pairwise("ak,ab->b", vec![vec![3, 7], vec![3, 2]], 5);
    check_pairwise("akz,abq->b", vec![vec![3, 2, 2], vec![3, 4, 3]], 6);
}

#[test]
fn conv1d_full_matches_reference() {
    let spec = parse("xa,xb->xab|x").unwrap();
    let s = SizedSpec::with_kinds(
        spec,
        vec![vec![6, 2], vec![3, 4]],
        vec![ConvKind::Full],
    )
    .unwrap();
    let mut rng = Rng::new(7);
    let inputs = rand_inputs(&s, &mut rng);
    let got = pairwise(&s, &inputs[0], &inputs[1]);
    assert_eq!(got.shape(), &[8, 2, 4]);
    let want = naive_eval(&s, &[&inputs[0], &inputs[1]]);
    got.assert_close(&want, 1e-3);
}

#[test]
fn conv1d_full_known_values() {
    // [1,2,3] * [1,1] = [1,3,5,3]
    let spec = parse("x,x->x|x").unwrap();
    let s = SizedSpec::with_kinds(spec, vec![vec![3], vec![2]], vec![ConvKind::Full]).unwrap();
    let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
    let b = Tensor::from_vec(&[2], vec![1.0, 1.0]);
    let y = pairwise(&s, &a, &b);
    assert_eq!(y.data(), &[1.0, 3.0, 5.0, 3.0]);
}

#[test]
fn conv1d_circular_known_values() {
    // circular [1,2,3,4] ⊛ [1,1] mod 4 = [1+4? ...]:
    // full = [1,3,5,7,4]; wrap index 4→0: [5,3,5,7]
    let spec = parse("x,x->x|x").unwrap();
    let s =
        SizedSpec::with_kinds(spec, vec![vec![4], vec![2]], vec![ConvKind::Circular]).unwrap();
    let a = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
    let b = Tensor::from_vec(&[2], vec![1.0, 1.0]);
    let y = pairwise(&s, &a, &b);
    assert_eq!(y.data(), &[5.0, 3.0, 5.0, 7.0]);
}

#[test]
fn conv_same_and_valid_match_reference() {
    for kind in [ConvKind::Same, ConvKind::Valid] {
        let spec = parse("xa,xb->xab|x").unwrap();
        let s = SizedSpec::with_kinds(spec, vec![vec![8, 2], vec![3, 2]], vec![kind]).unwrap();
        let mut rng = Rng::new(8);
        let inputs = rand_inputs(&s, &mut rng);
        let got = pairwise(&s, &inputs[0], &inputs[1]);
        let want = naive_eval(&s, &[&inputs[0], &inputs[1]]);
        got.assert_close(&want, 1e-3);
    }
}

#[test]
fn standard_conv_layer_matches_reference() {
    // §2.3: Y = conv_einsum("bshw,tshw->bthw|hw", X, W), Same padding.
    check_pairwise(
        "bshw,tshw->bthw|hw",
        vec![vec![2, 3, 6, 5], vec![4, 3, 3, 3]],
        9,
    );
}

#[test]
fn grouped_conv_atom_matches_reference() {
    // §3.1 atomic op: "gtsh,bgsh->bgth|h"
    check_pairwise(
        "gtsh,bgsh->bgth|h",
        vec![vec![2, 3, 2, 3], vec![2, 2, 2, 6]],
        10,
    );
}

#[test]
fn feature_filter_order_irrelevant() {
    // conv_einsum is symmetric in which operand carries the feature.
    let s1 = sized("bshw,tshw->bthw|hw", vec![vec![2, 3, 6, 6], vec![4, 3, 3, 3]]);
    let mut rng = Rng::new(11);
    let x = Tensor::rand(&[2, 3, 6, 6], -1.0, 1.0, &mut rng);
    let w = Tensor::rand(&[4, 3, 3, 3], -1.0, 1.0, &mut rng);
    let y1 = pairwise(&s1, &x, &w);
    let s2 = sized("tshw,bshw->bthw|hw", vec![vec![4, 3, 3, 3], vec![2, 3, 6, 6]]);
    let y2 = pairwise(&s2, &w, &x);
    y1.assert_close(&y2, 1e-4);
}

#[test]
fn vjp_matches_finite_differences() {
    let s = sized("bshw,tshw->bthw|hw", vec![vec![1, 2, 5, 4], vec![2, 2, 3, 3]]);
    let mut rng = Rng::new(12);
    let x = Tensor::rand(&[1, 2, 5, 4], -1.0, 1.0, &mut rng);
    let w = Tensor::rand(&[2, 2, 3, 3], -1.0, 1.0, &mut rng);
    // L = Σ out ⊙ dout for a fixed random dout.
    let out = pairwise(&s, &x, &w);
    let dout = Tensor::rand(out.shape(), -1.0, 1.0, &mut rng);
    let (dx, dw) = pairwise_vjp(&s, &x, &w, &dout);
    assert_eq!(dx.shape(), x.shape());
    assert_eq!(dw.shape(), w.shape());

    let loss = |x: &Tensor, w: &Tensor| -> f32 {
        let o = pairwise(&s, x, w);
        o.data().iter().zip(dout.data()).map(|(a, b)| a * b).sum()
    };
    let eps = 1e-2f32;
    // Check a handful of coordinates of each gradient.
    for k in [0usize, 7, 13, 29] {
        let mut xp = x.clone();
        xp.data_mut()[k] += eps;
        let mut xm = x.clone();
        xm.data_mut()[k] -= eps;
        let fd = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps);
        let an = dx.data()[k];
        assert!(
            (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
            "dx[{k}]: fd={fd} analytic={an}"
        );
    }
    for k in [0usize, 5, 17, 35] {
        let mut wp = w.clone();
        wp.data_mut()[k] += eps;
        let mut wm = w.clone();
        wm.data_mut()[k] -= eps;
        let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps);
        let an = dw.data()[k];
        assert!(
            (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
            "dw[{k}]: fd={fd} analytic={an}"
        );
    }
}

#[test]
fn vjp_with_selfsum_broadcasts() {
    let s = sized("ak,ab->b", vec![vec![2, 3], vec![2, 4]]);
    let mut rng = Rng::new(13);
    let a = Tensor::rand(&[2, 3], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[2, 4], -1.0, 1.0, &mut rng);
    let out = pairwise(&s, &a, &b);
    let dout = Tensor::full(out.shape(), 1.0);
    let (da, db) = pairwise_vjp(&s, &a, &b, &dout);
    assert_eq!(da.shape(), a.shape());
    assert_eq!(db.shape(), b.shape());
    // da[a,k] = Σ_b dout[b]·b[a,b] — independent of k (broadcast).
    for ai in 0..2 {
        assert!((da.at(&[ai, 0]) - da.at(&[ai, 1])).abs() < 1e-6);
        assert!((da.at(&[ai, 0]) - da.at(&[ai, 2])).abs() < 1e-6);
    }
}

#[test]
fn conv_einsum_end_to_end_cp_layer() {
    // Paper §2.3 CP convolutional layer, 5 inputs.
    let expr = "bshw,rt,rs,rh,rw->bthw|hw";
    let mut rng = Rng::new(14);
    let x = Tensor::rand(&[2, 3, 6, 6], -1.0, 1.0, &mut rng);
    let w1 = Tensor::rand(&[2, 4], -1.0, 1.0, &mut rng);
    let w2 = Tensor::rand(&[2, 3], -1.0, 1.0, &mut rng);
    let w3 = Tensor::rand(&[2, 6], -1.0, 1.0, &mut rng);
    let w4 = Tensor::rand(&[2, 6], -1.0, 1.0, &mut rng);
    let inputs = [&x, &w1, &w2, &w3, &w4];
    let opt = conv_einsum(expr, &inputs).unwrap();
    let ltr = conv_einsum_ltr(expr, &inputs).unwrap();
    assert_eq!(opt.shape(), &[2, 4, 6, 6]);
    // Optimal and naive paths compute the same tensor.
    opt.assert_close(&ltr, 1e-3);
    // And both match the brute-force reference.
    let s = sized(
        expr,
        inputs.iter().map(|t| t.shape().to_vec()).collect(),
    );
    let want = naive_eval(&s, &inputs);
    opt.assert_close(&want, 1e-3);
}

#[test]
fn conv_einsum_multiway_circular_path_independent() {
    // Interleaved group convolution (Eq. 2): h is a 3-way conv mode; any
    // pairwise order must agree under circular padding.
    let expr = "bfsh,fgh,sth->bgth|h";
    let mut rng = Rng::new(15);
    let x = Tensor::rand(&[2, 2, 3, 6], -1.0, 1.0, &mut rng);
    let k1 = Tensor::rand(&[2, 2, 3], -1.0, 1.0, &mut rng);
    let k2 = Tensor::rand(&[3, 2, 2], -1.0, 1.0, &mut rng);
    let inputs = [&x, &k1, &k2];
    let opt = conv_einsum(expr, &inputs).unwrap();
    let ltr = conv_einsum_ltr(expr, &inputs).unwrap();
    opt.assert_close(&ltr, 1e-3);
    let s = sized(expr, inputs.iter().map(|t| t.shape().to_vec()).collect());
    let want = naive_eval(&s, &inputs);
    opt.assert_close(&want, 1e-3);
    assert_eq!(opt.shape(), &[2, 2, 2, 6]);
}

#[test]
fn fig1_string_executes() {
    let expr = "ijk,jl,lmq,njpq->ijknp|j";
    let mut rng = Rng::new(16);
    let a = Tensor::rand(&[3, 4, 2], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[4, 3], -1.0, 1.0, &mut rng);
    let c = Tensor::rand(&[3, 2, 2], -1.0, 1.0, &mut rng);
    let d = Tensor::rand(&[2, 4, 3, 2], -1.0, 1.0, &mut rng);
    let inputs = [&a, &b, &c, &d];
    let got = conv_einsum(expr, &inputs).unwrap();
    let ltr = conv_einsum_ltr(expr, &inputs).unwrap();
    got.assert_close(&ltr, 1e-3);
    let s = sized(expr, inputs.iter().map(|t| t.shape().to_vec()).collect());
    got.assert_close(&naive_eval(&s, &inputs), 1e-3);
}

#[test]
fn single_input_expressions() {
    let mut rng = Rng::new(17);
    let x = Tensor::rand(&[2, 3, 4], -1.0, 1.0, &mut rng);
    // reduction
    let y = conv_einsum("abc->b", &[&x]).unwrap();
    let mut want = Tensor::zeros(&[3]);
    for a in 0..2 {
        for b in 0..3 {
            for c in 0..4 {
                let cur = want.at(&[b]);
                want.set(&[b], cur + x.at(&[a, b, c]));
            }
        }
    }
    y.assert_close(&want, 1e-4);
    // transpose
    let t = conv_einsum("abc->cab", &[&x]).unwrap();
    assert_eq!(t.shape(), &[4, 2, 3]);
    assert_eq!(t.at(&[3, 1, 2]), x.at(&[1, 2, 3]));
}

#[test]
fn property_pairwise_matches_reference() {
    // Random 2-input expressions over a small mode vocabulary.
    prop::check("pairwise-vs-reference", 60, |g| {
        let mut rng = Rng::new(g.usize_in(0, u32::MAX as usize) as u64);
        // choose structure: sizes for shared modes
        let n_shared = g.usize_in(0, 2); // contraction candidates
        let n_batch = g.usize_in(0, 1);
        let n_afree = g.usize_in(0, 2);
        let n_bfree = g.usize_in(0, 2);
        let with_conv = g.bool();

        let names = ["c", "d", "g", "t", "u", "n", "m", "x"];
        let mut lhs = String::new();
        let mut rhs = String::new();
        let mut out = String::new();
        let mut da: Vec<usize> = vec![];
        let mut db: Vec<usize> = vec![];
        let mut ni = 0;
        for _ in 0..n_shared {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            rhs.push_str(names[ni]);
            da.push(d);
            db.push(d);
            ni += 1;
        }
        for _ in 0..n_batch {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            rhs.push_str(names[ni]);
            out.push_str(names[ni]);
            da.push(d);
            db.push(d);
            ni += 1;
        }
        for _ in 0..n_afree {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            out.push_str(names[ni]);
            da.push(d);
            ni += 1;
        }
        for _ in 0..n_bfree {
            let d = g.usize_in(1, 3);
            rhs.push_str(names[ni]);
            out.push_str(names[ni]);
            db.push(d);
            ni += 1;
        }
        let mut conv_tail = String::new();
        if with_conv {
            let fa = g.usize_in(2, 6);
            let fb = g.usize_in(1, fa);
            lhs.push('x');
            rhs.push('x');
            out.push('x');
            conv_tail = "|x".to_string();
            da.push(fa);
            db.push(fb);
        }
        if lhs.is_empty() || rhs.is_empty() {
            return; // degenerate scalar operands — skip
        }
        let expr = format!("{lhs},{rhs}->{out}{conv_tail}");
        let s = sized(&expr, vec![da.clone(), db.clone()]);
        let a = Tensor::rand(&da, -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&db, -1.0, 1.0, &mut rng);
        let got = pairwise(&s, &a, &b);
        let want = naive_eval(&s, &[&a, &b]);
        got.assert_close(&want, 1e-3);
    });
}

// ---------------------------------------------------------------------------
// Parallel backend vs scalar backend vs brute-force reference
// ---------------------------------------------------------------------------

#[test]
fn parallel_backend_matches_scalar_and_reference_all_kinds() {
    // Deterministic sweep: every convolution variety × 1/2/4-thread pools.
    // The parallel conv kernels keep the scalar accumulation order per
    // output element, so scalar vs parallel must agree bit-for-bit here.
    for kind in [
        ConvKind::Same,
        ConvKind::Valid,
        ConvKind::Full,
        ConvKind::Circular,
    ] {
        let spec = parse("bsx,tsx->btx|x").unwrap();
        let s = SizedSpec::with_kinds(
            spec,
            vec![vec![2, 3, 9], vec![4, 3, 3]],
            vec![kind],
        )
        .unwrap();
        let mut rng = Rng::new(31);
        let a = Tensor::rand(&[2, 3, 9], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&[4, 3, 3], -1.0, 1.0, &mut rng);
        let scalar = pairwise_with(&s, &a, &b, &[], &ExecOptions::scalar());
        let want = naive_eval(&s, &[&a, &b]);
        scalar.assert_close(&want, 1e-3);
        for threads in [1usize, 2, 4] {
            let par = pairwise_with(&s, &a, &b, &[], &ExecOptions::parallel(threads));
            par.assert_close(&scalar, 0.0);
            par.assert_close(&want, 1e-3);
        }
    }
}

#[test]
fn parallel_backend_matches_scalar_on_2d_conv_layer() {
    // Two conv axes exercise the head-triples × runs decomposition.
    let s = sized(
        "bshw,tshw->bthw|hw",
        vec![vec![2, 3, 7, 6], vec![4, 3, 3, 3]],
    );
    let mut rng = Rng::new(32);
    let x = Tensor::rand(&[2, 3, 7, 6], -1.0, 1.0, &mut rng);
    let w = Tensor::rand(&[4, 3, 3, 3], -1.0, 1.0, &mut rng);
    let scalar = pairwise_with(&s, &x, &w, &[], &ExecOptions::scalar());
    for threads in [1usize, 2, 4] {
        let par = pairwise_with(&s, &x, &w, &[], &ExecOptions::parallel(threads));
        par.assert_close(&scalar, 0.0);
    }
    scalar.assert_close(&naive_eval(&s, &[&x, &w]), 1e-3);
}

#[test]
fn parallel_backend_respects_explicit_circular_moduli() {
    // Explicit wrap moduli arise for pairwise steps inside multi-way
    // circular convolutions; both backends must apply them identically.
    let spec = parse("xa,xb->xab|x").unwrap();
    for modulus in [4usize, 6, 8, 11] {
        let s = SizedSpec::with_kinds(
            spec.clone(),
            vec![vec![6, 2], vec![4, 3]],
            vec![ConvKind::Circular],
        )
        .unwrap();
        let mut rng = Rng::new(33 + modulus as u64);
        let a = Tensor::rand(&[6, 2], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&[4, 3], -1.0, 1.0, &mut rng);
        let moduli = vec![Some(modulus)];
        let scalar = pairwise_with(&s, &a, &b, &moduli, &ExecOptions::scalar());
        for threads in [1usize, 2, 4] {
            let par = pairwise_with(&s, &a, &b, &moduli, &ExecOptions::parallel(threads));
            par.assert_close(&scalar, 0.0);
        }
    }
}

#[test]
fn parallel_vjp_matches_scalar_vjp() {
    let s = sized("bshw,tshw->bthw|hw", vec![vec![1, 2, 5, 4], vec![2, 2, 3, 3]]);
    let mut rng = Rng::new(34);
    let x = Tensor::rand(&[1, 2, 5, 4], -1.0, 1.0, &mut rng);
    let w = Tensor::rand(&[2, 2, 3, 3], -1.0, 1.0, &mut rng);
    let out = pairwise(&s, &x, &w);
    let dout = Tensor::rand(out.shape(), -1.0, 1.0, &mut rng);
    let (dx_s, dw_s) = pairwise_vjp_with(&s, &x, &w, &dout, &[], &ExecOptions::scalar());
    for threads in [1usize, 2, 4] {
        let (dx_p, dw_p) =
            pairwise_vjp_with(&s, &x, &w, &dout, &[], &ExecOptions::parallel(threads));
        dx_p.assert_close(&dx_s, 0.0);
        dw_p.assert_close(&dw_s, 0.0);
    }
    // Pure contraction vjp (matmul kernels) under the parallel backend.
    let m = sized("gts,gns->gtn", vec![vec![3, 4, 5], vec![3, 6, 5]]);
    let a = Tensor::rand(&[3, 4, 5], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[3, 6, 5], -1.0, 1.0, &mut rng);
    let o = pairwise(&m, &a, &b);
    let do_ = Tensor::rand(o.shape(), -1.0, 1.0, &mut rng);
    let (da_s, db_s) = pairwise_vjp_with(&m, &a, &b, &do_, &[], &ExecOptions::scalar());
    let (da_p, db_p) = pairwise_vjp_with(&m, &a, &b, &do_, &[], &ExecOptions::parallel(4));
    da_p.assert_close(&da_s, 0.0);
    db_p.assert_close(&db_s, 0.0);
}

#[test]
fn property_parallel_backend_matches_reference() {
    // Randomized 2-input specs sweeping structure, all four convolution
    // varieties and 1/2/4-thread pools, checked against the brute-force
    // reference and against the scalar backend.
    prop::check("parallel-vs-reference", 40, |g| {
        let mut rng = Rng::new(g.usize_in(0, u32::MAX as usize) as u64);
        let n_shared = g.usize_in(0, 2);
        let n_batch = g.usize_in(0, 1);
        let n_afree = g.usize_in(0, 2);
        let n_bfree = g.usize_in(0, 2);
        let kind = *g.pick(&[
            ConvKind::Same,
            ConvKind::Valid,
            ConvKind::Full,
            ConvKind::Circular,
        ]);
        let threads = *g.pick(&[1usize, 2, 4]);

        let names = ["c", "d", "g", "t", "u", "n", "m", "x"];
        let mut lhs = String::new();
        let mut rhs = String::new();
        let mut out = String::new();
        let mut da: Vec<usize> = vec![];
        let mut db: Vec<usize> = vec![];
        let mut ni = 0;
        for _ in 0..n_shared {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            rhs.push_str(names[ni]);
            da.push(d);
            db.push(d);
            ni += 1;
        }
        for _ in 0..n_batch {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            rhs.push_str(names[ni]);
            out.push_str(names[ni]);
            da.push(d);
            db.push(d);
            ni += 1;
        }
        for _ in 0..n_afree {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            out.push_str(names[ni]);
            da.push(d);
            ni += 1;
        }
        for _ in 0..n_bfree {
            let d = g.usize_in(1, 3);
            rhs.push_str(names[ni]);
            out.push_str(names[ni]);
            db.push(d);
            ni += 1;
        }
        // Always include a conv mode: the backend split is what we test.
        let fa = g.usize_in(2, 6);
        let fb = g.usize_in(1, fa);
        lhs.push('x');
        rhs.push('x');
        out.push('x');
        da.push(fa);
        db.push(fb);
        let expr = format!("{lhs},{rhs}->{out}|x");
        let spec = parse(&expr).unwrap();
        let s = SizedSpec::with_kinds(spec, vec![da.clone(), db.clone()], vec![kind]).unwrap();
        let a = Tensor::rand(&da, -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&db, -1.0, 1.0, &mut rng);
        let par = pairwise_with(&s, &a, &b, &[], &ExecOptions::parallel(threads));
        let scalar = pairwise_with(&s, &a, &b, &[], &ExecOptions::scalar());
        let want = naive_eval(&s, &[&a, &b]);
        par.assert_close(&scalar, 1e-5);
        par.assert_close(&want, 1e-3);
    });
}

#[test]
fn multiway_circular_parallel_path_matches_reference() {
    // Multi-way circular conv: pairwise steps carry explicit wrap moduli
    // through execute_path; the parallel backend must agree with the
    // reference and with a scalar-backend plan.
    let expr = "bfsh,fgh,sth->bgth|h";
    let mut rng = Rng::new(35);
    let x = Tensor::rand(&[2, 2, 3, 6], -1.0, 1.0, &mut rng);
    let k1 = Tensor::rand(&[2, 2, 3], -1.0, 1.0, &mut rng);
    let k2 = Tensor::rand(&[3, 2, 2], -1.0, 1.0, &mut rng);
    let inputs = [&x, &k1, &k2];
    let par = conv_einsum_with(
        expr,
        &inputs,
        &PlanOptions {
            backend: Backend::Parallel { threads: 4 },
            ..Default::default()
        },
    )
    .unwrap();
    let scalar = conv_einsum_with(
        expr,
        &inputs,
        &PlanOptions {
            backend: Backend::Scalar,
            ..Default::default()
        },
    )
    .unwrap();
    par.assert_close(&scalar, 0.0);
    let s = sized(expr, inputs.iter().map(|t| t.shape().to_vec()).collect());
    par.assert_close(&naive_eval(&s, &inputs), 1e-3);
}

#[test]
fn execute_path_with_overrides_plan_backend() {
    use crate::planner::contract_path;
    let expr = "ij,jk,kl->il";
    let dims = vec![vec![2, 3], vec![3, 4], vec![4, 5]];
    let plan = contract_path(
        expr,
        &dims,
        &PlanOptions {
            backend: Backend::Scalar,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(plan.backend, Backend::Scalar);
    let mut rng = Rng::new(36);
    let ts: Vec<Tensor> = dims
        .iter()
        .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
        .collect();
    let refs: Vec<&Tensor> = ts.iter().collect();
    let via_plan = execute_path(&plan, &refs).unwrap();
    let via_override = execute_path_with(&plan, &refs, &ExecOptions::parallel(2)).unwrap();
    via_override.assert_close(&via_plan, 1e-5);
}

// ---------------------------------------------------------------------------
// Compiled-plan engine: bit-identical replays, workspace reuse, invalidation
// ---------------------------------------------------------------------------

#[test]
fn compiled_rerun_bit_identical_all_kinds_and_backends() {
    // 100 replays against one workspace, every convolution variety, scalar
    // and parallel backends: each run must be bit-identical to a fresh
    // conv_einsum call (same kernels, same accumulation order, no stale
    // workspace state).
    for kind in [
        ConvKind::Same,
        ConvKind::Valid,
        ConvKind::Full,
        ConvKind::Circular,
    ] {
        for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
            let expr = "bsx,tsx->btx|x";
            let dims = vec![vec![2, 3, 9], vec![4, 3, 3]];
            let opts = PlanOptions {
                backend,
                conv_kinds: Some(vec![kind]),
                ..Default::default()
            };
            let mut rng = Rng::new(41);
            let a = Tensor::rand(&dims[0], -1.0, 1.0, &mut rng);
            let b = Tensor::rand(&dims[1], -1.0, 1.0, &mut rng);
            let inputs = [&a, &b];
            let fresh = conv_einsum_with(expr, &inputs, &opts).unwrap();
            let compiled = compile_expr(expr, &dims, &opts).unwrap();
            let mut ws = Workspace::new();
            for _ in 0..100 {
                let got = compiled.run(&inputs, &mut ws).unwrap();
                got.assert_close(&fresh, 0.0);
            }
        }
    }
}

#[test]
fn compiled_multiway_rerun_matches_fresh_and_reference() {
    // A 5-input CP layer: the liveness allocator actually reuses arena
    // ranges here, and the plan ends with a final permutation.
    let expr = "bshw,rt,rs,rh,rw->bthw|hw";
    let dims = vec![
        vec![2, 3, 6, 6],
        vec![2, 4],
        vec![2, 3],
        vec![2, 3],
        vec![2, 3],
    ];
    let mut rng = Rng::new(42);
    let tensors: Vec<Tensor> = dims
        .iter()
        .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
        .collect();
    let inputs: Vec<&Tensor> = tensors.iter().collect();
    for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
        let opts = PlanOptions {
            backend,
            ..Default::default()
        };
        let fresh = conv_einsum_with(expr, &inputs, &opts).unwrap();
        let compiled = compile_expr(expr, &dims, &opts).unwrap();
        let mut ws = Workspace::new();
        for _ in 0..25 {
            let got = compiled.run(&inputs, &mut ws).unwrap();
            got.assert_close(&fresh, 0.0);
        }
        let s = sized(expr, dims.clone());
        fresh.assert_close(&naive_eval(&s, &inputs), 1e-3);
    }
}

#[test]
fn compiled_presum_path_matches_fresh_and_reference() {
    // One-sided non-output modes (k, z, q) exercise the workspace pre-sum
    // ping-pong chain.
    let expr = "akz,abq->b";
    let dims = vec![vec![3, 2, 2], vec![3, 4, 3]];
    let mut rng = Rng::new(43);
    let a = Tensor::rand(&dims[0], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&dims[1], -1.0, 1.0, &mut rng);
    let inputs = [&a, &b];
    for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
        let opts = PlanOptions {
            backend,
            ..Default::default()
        };
        let fresh = conv_einsum_with(expr, &inputs, &opts).unwrap();
        let compiled = compile_expr(expr, &dims, &opts).unwrap();
        let mut ws = Workspace::new();
        for _ in 0..30 {
            compiled.run(&inputs, &mut ws).unwrap().assert_close(&fresh, 0.0);
        }
    }
    let s = sized(expr, dims);
    let fresh = conv_einsum(expr, &inputs).unwrap();
    fresh.assert_close(&naive_eval(&s, &inputs), 1e-3);
}

#[test]
fn property_compiled_replay_bit_identical() {
    // Random 2-input structures × all conv varieties × both backends:
    // compile once, replay three times against one workspace, compare
    // bit-for-bit with a fresh conv_einsum call and (tolerantly) with the
    // brute-force reference.
    prop::check("compiled-replay-vs-fresh", 30, |g| {
        let mut rng = Rng::new(g.usize_in(0, u32::MAX as usize) as u64);
        let n_shared = g.usize_in(0, 2);
        let n_batch = g.usize_in(0, 1);
        let n_afree = g.usize_in(0, 2);
        let n_bfree = g.usize_in(0, 2);
        let kind = *g.pick(&[
            ConvKind::Same,
            ConvKind::Valid,
            ConvKind::Full,
            ConvKind::Circular,
        ]);
        let backend = *g.pick(&[Backend::Scalar, Backend::Parallel { threads: 2 }]);

        let names = ["c", "d", "g", "t", "u", "n", "m", "x"];
        let mut lhs = String::new();
        let mut rhs = String::new();
        let mut out = String::new();
        let mut da: Vec<usize> = vec![];
        let mut db: Vec<usize> = vec![];
        let mut ni = 0;
        for _ in 0..n_shared {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            rhs.push_str(names[ni]);
            da.push(d);
            db.push(d);
            ni += 1;
        }
        for _ in 0..n_batch {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            rhs.push_str(names[ni]);
            out.push_str(names[ni]);
            da.push(d);
            db.push(d);
            ni += 1;
        }
        for _ in 0..n_afree {
            let d = g.usize_in(1, 3);
            lhs.push_str(names[ni]);
            out.push_str(names[ni]);
            da.push(d);
            ni += 1;
        }
        for _ in 0..n_bfree {
            let d = g.usize_in(1, 3);
            rhs.push_str(names[ni]);
            out.push_str(names[ni]);
            db.push(d);
            ni += 1;
        }
        let fa = g.usize_in(2, 6);
        let fb = g.usize_in(1, fa);
        lhs.push('x');
        rhs.push('x');
        out.push('x');
        da.push(fa);
        db.push(fb);
        let expr = format!("{lhs},{rhs}->{out}|x");
        let dims = vec![da.clone(), db.clone()];
        let opts = PlanOptions {
            backend,
            conv_kinds: Some(vec![kind]),
            ..Default::default()
        };
        let a = Tensor::rand(&da, -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&db, -1.0, 1.0, &mut rng);
        let inputs = [&a, &b];
        let fresh = conv_einsum_with(&expr, &inputs, &opts).unwrap();
        let compiled = compile_expr(&expr, &dims, &opts).unwrap();
        let mut ws = Workspace::new();
        for _ in 0..3 {
            compiled.run(&inputs, &mut ws).unwrap().assert_close(&fresh, 0.0);
        }
        let spec = parse(&expr).unwrap();
        let s = SizedSpec::with_kinds(spec, dims, vec![kind]).unwrap();
        fresh.assert_close(&naive_eval(&s, &inputs), 1e-3);
    });
}

#[test]
fn compiled_plan_rejects_shape_change() {
    let expr = "ij,jk->ik";
    let dims = vec![vec![3, 4], vec![4, 5]];
    let compiled = compile_expr(expr, &dims, &PlanOptions::default()).unwrap();
    let mut rng = Rng::new(44);
    let a = Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng);
    let b_bad = Tensor::rand(&[4, 6], -1.0, 1.0, &mut rng);
    let mut ws = Workspace::new();
    let err = compiled.run(&[&a, &b_bad], &mut ws).unwrap_err();
    assert!(
        format!("{err}").contains("recompile"),
        "shape-change error should instruct recompilation: {err}"
    );
    // Wrong arity is also rejected.
    assert!(compiled.run(&[&a], &mut ws).is_err());
    // A matching call still works afterwards (the failed run left no state).
    let b_ok = Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng);
    assert!(compiled.run(&[&a, &b_ok], &mut ws).is_ok());
}

#[test]
fn plan_cache_reuses_and_keys_by_shape_and_backend() {
    use std::sync::Arc;
    let cache = PlanCache::new();
    let opts = PlanOptions::default();
    let d1 = vec![vec![3, 4], vec![4, 5]];
    let c1 = cache.get_or_compile("ij,jk->ik", &d1, &opts).unwrap();
    let c2 = cache.get_or_compile("ij,jk->ik", &d1, &opts).unwrap();
    assert!(Arc::ptr_eq(&c1, &c2), "same key must hit the cache");
    assert_eq!(cache.misses(), 1);
    assert_eq!(cache.hits(), 1);
    // Different shapes → different compiled entry.
    let d2 = vec![vec![3, 4], vec![4, 7]];
    let c3 = cache.get_or_compile("ij,jk->ik", &d2, &opts).unwrap();
    assert!(!Arc::ptr_eq(&c1, &c3));
    // Different backend → different compiled entry.
    let sopts = PlanOptions {
        backend: Backend::Scalar,
        ..Default::default()
    };
    let c4 = cache.get_or_compile("ij,jk->ik", &d1, &sopts).unwrap();
    assert!(!Arc::ptr_eq(&c1, &c4));
    // Different planning constraints → different compiled entry (the key
    // covers every option the tree selection depends on).
    let strict = PlanOptions {
        max_dp_inputs: 0, // forces the greedy fallback
        ..Default::default()
    };
    let c5 = cache.get_or_compile("ij,jk->ik", &d1, &strict).unwrap();
    assert!(!Arc::ptr_eq(&c1, &c5));
    let capped = PlanOptions {
        cost_cap: Some(1e18),
        ..Default::default()
    };
    let c6 = cache.get_or_compile("ij,jk->ik", &d1, &capped).unwrap();
    assert!(!Arc::ptr_eq(&c1, &c6));
    assert_eq!(cache.len(), 5);
    cache.clear();
    assert!(cache.is_empty());
}

#[test]
fn plan_cache_evicts_least_recently_used() {
    use std::sync::Arc;
    let cache = PlanCache::with_capacity(2);
    assert_eq!(cache.capacity(), 2);
    let opts = PlanOptions::default();
    let d = |k: usize| vec![vec![3, 4], vec![4, k]];
    let c1 = cache.get_or_compile("ij,jk->ik", &d(5), &opts).unwrap();
    let _c2 = cache.get_or_compile("ij,jk->ik", &d(6), &opts).unwrap();
    // Touch the first entry so the second becomes least-recently-used…
    let c1b = cache.get_or_compile("ij,jk->ik", &d(5), &opts).unwrap();
    assert!(Arc::ptr_eq(&c1, &c1b));
    // …then a third key must evict it, keeping the cache at capacity.
    let _c3 = cache.get_or_compile("ij,jk->ik", &d(7), &opts).unwrap();
    assert_eq!(cache.len(), 2);
    let misses_before = cache.misses();
    let _ = cache.get_or_compile("ij,jk->ik", &d(5), &opts).unwrap();
    assert_eq!(cache.misses(), misses_before, "recently-used entry survived");
    let _ = cache.get_or_compile("ij,jk->ik", &d(6), &opts).unwrap();
    assert_eq!(cache.misses(), misses_before + 1, "LRU entry was evicted");
}

#[test]
fn one_workspace_serves_many_plans() {
    // A workspace is plan-agnostic scratch: alternating plans of different
    // shapes through one workspace must not corrupt results.
    let e1 = "ij,jk->ik";
    let d1 = vec![vec![3, 4], vec![4, 5]];
    let e2 = "bshw,tshw->bthw|hw";
    let d2 = vec![vec![2, 3, 6, 5], vec![4, 3, 3, 3]];
    let c1 = compile_expr(e1, &d1, &PlanOptions::default()).unwrap();
    let c2 = compile_expr(e2, &d2, &PlanOptions::default()).unwrap();
    let mut rng = Rng::new(45);
    let a = Tensor::rand(&d1[0], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&d1[1], -1.0, 1.0, &mut rng);
    let x = Tensor::rand(&d2[0], -1.0, 1.0, &mut rng);
    let w = Tensor::rand(&d2[1], -1.0, 1.0, &mut rng);
    let want1 = conv_einsum(e1, &[&a, &b]).unwrap();
    let want2 = conv_einsum(e2, &[&x, &w]).unwrap();
    let mut ws = Workspace::new();
    for _ in 0..5 {
        c1.run(&[&a, &b], &mut ws).unwrap().assert_close(&want1, 0.0);
        c2.run(&[&x, &w], &mut ws).unwrap().assert_close(&want2, 0.0);
    }
    assert!(ws.bytes() >= c1.workspace_bytes().max(c2.workspace_bytes()) / 2);
}

#[test]
fn run_into_reuses_caller_output() {
    let expr = "bsx,tsx->btx|x";
    let dims = vec![vec![2, 3, 9], vec![4, 3, 3]];
    let compiled = compile_expr(expr, &dims, &PlanOptions::default()).unwrap();
    let mut rng = Rng::new(46);
    let a = Tensor::rand(&dims[0], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&dims[1], -1.0, 1.0, &mut rng);
    let want = conv_einsum(expr, &[&a, &b]).unwrap();
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(compiled.out_shape());
    for _ in 0..10 {
        compiled.run_into(&[&a, &b], &mut ws, &mut out).unwrap();
        out.assert_close(&want, 0.0);
    }
    // Shape-mismatched output buffers are rejected.
    let mut bad = Tensor::zeros(&[1, 2, 3]);
    assert!(compiled.run_into(&[&a, &b], &mut ws, &mut bad).is_err());
}

#[test]
fn property_optimal_path_equals_ltr_numerically() {
    // Whatever order the planner picks, the numbers must agree with LTR.
    prop::check("path-order-independence", 30, |g| {
        let mut rng = Rng::new(g.usize_in(0, u32::MAX as usize) as u64);
        let r = g.usize_in(1, 3);
        let t = g.usize_in(1, 3);
        let s_ = g.usize_in(1, 3);
        let hf = g.usize_in(3, 6);
        let hk = g.usize_in(1, 3);
        let b = g.usize_in(1, 2);
        // CP-style layer in 1D: "bsh,rt,rs,rh->bth|h"
        let expr = "bsh,rt,rs,rh->bth|h";
        let dims = vec![
            vec![b, s_, hf],
            vec![r, t],
            vec![r, s_],
            vec![r, hk],
        ];
        let sspec = sized(expr, dims.clone());
        let inputs: Vec<Tensor> = dims
            .iter()
            .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let opt = conv_einsum(expr, &refs).unwrap();
        let ltr = conv_einsum_ltr(expr, &refs).unwrap();
        opt.assert_close(&ltr, 1e-3);
        let want = naive_eval(&sspec, &refs);
        opt.assert_close(&want, 1e-3);
    });
}
