//! Brute-force reference evaluator for N-input conv_einsum expressions.
//!
//! Exponential-time (it enumerates the full cross product of every mode
//! occurrence) but trivially correct from the paper's defining summations
//! (Appendix A.2). Used as the oracle in unit/property tests for the
//! pairwise executor, the path executor and the autodiff — *never* on a hot
//! path.
//!
//! Semantics fixed here (and mirrored by `python/compile/kernels/ref.py`):
//!
//! * non-conv shared modes: one shared index (batch if in output,
//!   contraction otherwise);
//! * self-sum modes: free summation index of their input;
//! * a convolution mode contributes `p_full = Σ occurrence indices`, then
//!   per variety: Full keeps `p_full`; Same shifts by `(filt−1)/2` and
//!   crops; Valid shifts by `filt−1` and crops; Circular wraps modulo the
//!   feature (max occurrence) size. True convolution, not correlation.

// alloc-ok(file): test-only oracle, never on a hot path.

use crate::einsum::{ConvKind, ModeId, SizedSpec};
use crate::tensor::{for_each_index, Tensor};

/// Evaluate a sized conv_einsum over `inputs` by direct summation.
pub fn naive_eval(sized: &SizedSpec, inputs: &[&Tensor]) -> Tensor {
    let spec = &sized.spec;
    assert_eq!(inputs.len(), spec.n_inputs());
    for (i, t) in inputs.iter().enumerate() {
        assert_eq!(
            t.shape(),
            &sized.dims[i][..],
            "input {} shape mismatch",
            i
        );
    }

    let out_shape = sized.output_shape();
    let mut out = Tensor::zeros(&out_shape);

    // Enumerate one index per *occurrence* for conv modes and per *mode*
    // otherwise. Build the enumeration axis list:
    //   - every non-conv mode (shared index across occurrences)
    //   - every (input, position) occurrence of every conv mode
    #[derive(Clone, Copy)]
    enum Axis {
        Shared(ModeId, usize),          // mode, size
        ConvOcc(ModeId, usize, usize),  // mode, input idx, size
    }

    let mut axes: Vec<Axis> = Vec::new();
    for m in spec.all_modes() {
        if spec.is_conv(m) {
            for (i, modes) in spec.inputs.iter().enumerate() {
                if let Some(pos) = modes.iter().position(|&x| x == m) {
                    axes.push(Axis::ConvOcc(m, i, sized.dims[i][pos]));
                }
            }
        } else {
            axes.push(Axis::Shared(m, sized.mode_size(m)));
        }
    }
    let sizes: Vec<usize> = axes
        .iter()
        .map(|a| match *a {
            Axis::Shared(_, s) | Axis::ConvOcc(_, _, s) => s,
        })
        .collect();

    // Per conv mode: variety, shift, output size, feature size.
    struct ConvInfo {
        mode: ModeId,
        kind: ConvKind,
        out_size: usize,
        shift: usize,
        feature: usize,
    }
    let conv_infos: Vec<ConvInfo> = spec
        .conv
        .iter()
        .map(|&m| {
            let occ = sized.occurrence_sizes(m);
            let feature = *occ.iter().max().unwrap();
            let filt = *occ.iter().min().unwrap();
            let kind = sized.conv_kind(m);
            let out_size = if occ.len() == 1 {
                occ[0]
            } else {
                match kind {
                    ConvKind::Circular | ConvKind::Same => feature,
                    ConvKind::Full => occ.iter().sum::<usize>() - (occ.len() - 1),
                    ConvKind::Valid => feature - filt + 1,
                }
            };
            let shift = match kind {
                ConvKind::Same => (filt - 1) / 2,
                ConvKind::Valid => filt - 1,
                _ => 0,
            };
            ConvInfo {
                mode: m,
                kind,
                out_size,
                shift,
                feature,
            }
        })
        .collect();

    for_each_index(&sizes, |idx| {
        // index of each non-conv mode:
        let mode_val = |m: ModeId| -> usize {
            axes.iter()
                .zip(idx.iter())
                .find_map(|(a, &v)| match *a {
                    Axis::Shared(mm, _) if mm == m => Some(v),
                    _ => None,
                })
                .unwrap()
        };
        // index of a conv occurrence:
        let occ_val = |m: ModeId, input: usize| -> usize {
            axes.iter()
                .zip(idx.iter())
                .find_map(|(a, &v)| match *a {
                    Axis::ConvOcc(mm, i, _) if mm == m && i == input => Some(v),
                    _ => None,
                })
                .unwrap()
        };

        // Output index per conv mode; None ⇒ this combination is cropped.
        let mut conv_out: Vec<Option<usize>> = Vec::with_capacity(conv_infos.len());
        for ci in &conv_infos {
            let p_full: usize = spec
                .inputs
                .iter()
                .enumerate()
                .filter(|(_, modes)| modes.contains(&ci.mode))
                .map(|(i, _)| occ_val(ci.mode, i))
                .sum();
            let p = match ci.kind {
                ConvKind::Circular => {
                    // wraps modulo feature size; but when the support
                    // min(Σsizes−k+1, feature) never reaches the modulus the
                    // mod is a no-op, matching the pairwise executor.
                    Some(p_full % ci.feature.max(1) % ci.out_size.max(1))
                }
                ConvKind::Full => Some(p_full),
                ConvKind::Same | ConvKind::Valid => {
                    let p = p_full as isize - ci.shift as isize;
                    (p >= 0 && (p as usize) < ci.out_size).then_some(p as usize)
                }
            };
            conv_out.push(p);
        }
        if conv_out.iter().any(|p| p.is_none()) {
            return;
        }

        // Product over inputs.
        let mut prod = 1.0f32;
        for (i, modes) in spec.inputs.iter().enumerate() {
            let mut ix = Vec::with_capacity(modes.len());
            for &m in modes {
                if spec.is_conv(m) {
                    ix.push(occ_val(m, i));
                } else {
                    ix.push(mode_val(m));
                }
            }
            prod *= inputs[i].at(&ix);
            if prod == 0.0 {
                // keep going: zeros are common but cheap anyway
            }
        }

        // Output index.
        let mut oix = Vec::with_capacity(spec.output.len());
        for &m in &spec.output {
            if spec.is_conv(m) {
                let k = spec.conv.iter().position(|&x| x == m).unwrap();
                oix.push(conv_out[k].unwrap());
            } else {
                oix.push(mode_val(m));
            }
        }
        let cur = out.at(&oix);
        out.set(&oix, cur + prod);
    });

    out
}
