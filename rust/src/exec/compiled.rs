//! Compile-once, run-many execution engine.
//!
//! The paper's thesis is that the evaluation *path* through a tensorial
//! convolution determines its cost — but in a training or serving loop the
//! same expression with the same shapes executes millions of times, and
//! re-discovering the path (parse → plan → canonicalize every atom →
//! allocate every intermediate) on each call wastes most of the win. This
//! module lowers a [`Plan`] **once** into a [`CompiledPlan`]:
//!
//! * every step carries its precomputed [`Atom`] (pre-sum axes, canonical
//!   permutations, conv triple tables) and [`AtomKernel`] (head/run/combined
//!   tables plus the step's selected SIMD microkernel,
//!   [`crate::kernels::StepKernel`]), so replays do zero canonicalization
//!   analysis;
//! * a liveness-based workspace layout assigns every intermediate a range in
//!   a value arena, reusing ranges as soon as their producer dies — the
//!   caller holds the [`Workspace`] and hands it back on every call, so the
//!   steady-state path performs **no heap allocations** after warm-up on
//!   *both* backends (the parallel backend dispatches to the persistent
//!   worker pool instead of spawning scoped threads; `bench_hotpath`
//!   asserts zero steady-state allocations for scalar and parallel alike);
//! * input canonicalization (permute / pre-sum) runs through the
//!   workspace-backed [`crate::tensor::permute_into`] /
//!   [`crate::tensor::sum_axis_into`] kernels, optionally fanned out over
//!   the worker pool — the previously single-threaded stretch of the hot
//!   path.
//!
//! # Training engine
//!
//! The same compile-once philosophy covers training: a compiled plan
//! lazily builds one [`TrainLayout`] per checkpoint policy
//! ([`crate::autodiff::CkptPolicy`]) by *simulating* the stored-forward +
//! backward schedule (including checkpoint-segment recomputes) against a
//! compile-time arena allocator, assigning a slot to every input copy,
//! tape value and cotangent. [`CompiledPlan::train_forward`] /
//! [`CompiledPlan::train_backward`] replay that schedule against a
//! caller-held [`TrainWorkspace`] — zero steady-state heap allocations on
//! both backends, gradients bit-identical to the per-value heap tape
//! (`tests/train_parity.rs` replays the old algorithm and compares bits).
//! [`crate::autodiff::PathAutodiff`] is the user-facing wrapper.
//!
//! # Workspace ownership
//!
//! A [`Workspace`] is plan-agnostic scratch capacity: it grows to fit
//! whatever plan runs against it and holds no results between calls, so one
//! workspace per thread serves any number of compiled plans (the
//! coordinator gives each worker one). It is `Send` but not shareable —
//! runs need `&mut`. A [`TrainWorkspace`] extends it with the training
//! arena (shared with the inference value arena) and backward scratch.
//!
//! # Invalidation
//!
//! A compiled plan is specialized to exact input shapes (and the backend /
//! strategy recorded at planning time). [`CompiledPlan::run`] rejects
//! mismatched shapes with an error telling the caller to recompile; layer
//! caches key compiled plans by `(batch, height, width)` and the shared
//! [`PlanCache`] keys them by [`PlanKey`] `(expr, dims, backend, strategy,
//! training, conv kinds)`.
//!
//! # Determinism
//!
//! Replays are bit-identical to a fresh [`crate::exec::conv_einsum`] call:
//! the canonicalization kernels replicate `Tensor::sum_axis` /
//! `Tensor::permute` accumulation orders exactly, and the step kernels are
//! the same code both paths execute.

use crate::autodiff::CkptPolicy;
use crate::einsum::{parse, ConvKind, EinsumSpec, SizedSpec};
use crate::exec::atom::{canonicalize, Atom, AtomKernel, PackBufs};
use crate::exec::{Backend, ExecOptions};
use crate::parallel::Pool;
use crate::planner::{plan_with, Plan, PlanOptions, Strategy};
use crate::tensor::{gather_into, permute_into, strides_for, sum_axis_into, Tensor};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Where a step operand's flat data lives at run time.
#[derive(Debug, Clone)]
pub(crate) enum Operand {
    /// Caller-provided input tensor `i`.
    Input(usize),
    /// Intermediate produced by an earlier step, at this value-arena range.
    Value(Range<usize>),
}

/// Fully-resolved canonicalization recipe for one operand: every pre-sum
/// stage's shape is precomputed, so replays do no shape bookkeeping (and no
/// allocation).
#[derive(Debug, Clone)]
struct CanonOp {
    /// (input shape, axis to sum) per pre-sum stage, in execution order.
    sums: Vec<(Vec<usize>, usize)>,
    /// Shape after all pre-sums (input to the permutation).
    post_shape: Vec<usize>,
    /// Canonical permutation (the atom's `perm_a`/`perm_b`).
    perm: Vec<usize>,
    /// No pre-sums and an identity permutation: read the source in place.
    identity: bool,
}

// alloc-ok(fn): canonicalization recipes are resolved once at compile time.
fn canon_op(dims: &[usize], presum: &[usize], perm: &[usize]) -> CanonOp {
    let mut shape = dims.to_vec();
    let mut sums = Vec::with_capacity(presum.len());
    for &ax in presum {
        sums.push((shape.clone(), ax));
        shape.remove(ax);
    }
    let identity = sums.is_empty() && is_identity(perm);
    CanonOp {
        sums,
        post_shape: shape,
        perm: perm.to_vec(),
        identity,
    }
}

/// Fused VJP un-canonicalization recipe for one operand: the cotangent the
/// backward kernels produce is in the operand's *canonical* flat layout;
/// gathering it back to the operand's natural layout is an inverse permute
/// followed by re-broadcasting every pre-summed axis. Both collapse into a
/// single strided gather (broadcast axes carry stride 0), resolved at
/// compile time so the replay allocates nothing.
#[derive(Debug, Clone)]
pub(crate) struct GradGather {
    /// The operand's natural (working-list) shape.
    pub(crate) out_shape: Vec<usize>,
    /// Per output axis, its stride into the canonical flat buffer
    /// (0 = broadcast of a pre-summed axis).
    pub(crate) strides: Vec<usize>,
}

/// Build the [`GradGather`] for an operand with natural shape `dims`,
/// pre-summed axes `presum` (descending, as the atom records them) and
/// canonical permutation `perm`. Element-for-element identical to
/// `permute(invert(perm))` followed by ascending `broadcast_axis` calls —
/// the allocating path the heap tape used.
// alloc-ok(fn): gather tables are resolved once at compile time.
fn grad_gather(dims: &[usize], presum: &[usize], perm: &[usize]) -> GradGather {
    let rank = dims.len();
    let mut is_presum = vec![false; rank];
    for &ax in presum {
        is_presum[ax] = true;
    }
    let post_shape: Vec<usize> = (0..rank)
        .filter(|&ax| !is_presum[ax])
        .map(|ax| dims[ax])
        .collect();
    // Canonical buffer shape and row-major strides.
    let cs: Vec<usize> = perm.iter().map(|&p| post_shape[p]).collect();
    let sc = strides_for(&cs);
    let inv = invert_perm(perm);
    let mut strides = vec![0usize; rank];
    let mut post_ax = 0usize;
    for (ax, stride) in strides.iter_mut().enumerate() {
        if !is_presum[ax] {
            *stride = sc[inv[post_ax]];
            post_ax += 1;
        }
    }
    GradGather {
        out_shape: dims.to_vec(),
        strides,
    }
}

// alloc-ok(fn): compile-time helper.
fn invert_perm(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

/// One fully-resolved step of a compiled plan.
#[derive(Debug, Clone)]
pub struct CompiledStep {
    /// DAG node ids (inputs are `0..n`; step `k` produces node `n + k`).
    pub(crate) lhs_node: usize,
    pub(crate) rhs_node: usize,
    /// Run-time locations of the operands' flat data.
    pub(crate) lhs_src: Operand,
    pub(crate) rhs_src: Operand,
    /// Canonicalization recipes for the two operands.
    canon_a: CanonOp,
    canon_b: CanonOp,
    /// Value-arena range receiving this step's output (post `out_perm`).
    pub(crate) out: Range<usize>,
    /// Whether `atom.out_perm` is the identity (raw layout == working-list
    /// layout), precomputed so replays skip the per-run check.
    out_identity: bool,
    /// Inverse of `atom.out_perm`: takes a working-list-layout cotangent
    /// back to the raw kernel layout the backward kernels consume.
    pub(crate) inv_out_perm: Vec<usize>,
    /// VJP un-canonicalization gathers for the two operands.
    pub(crate) grad_a: GradGather,
    pub(crate) grad_b: GradGather,
    pub(crate) atom: Atom,
    pub(crate) kernel: AtomKernel,
}

impl CompiledStep {
    pub fn atom(&self) -> &Atom {
        &self.atom
    }

    pub fn kernel_tables(&self) -> &AtomKernel {
        &self.kernel
    }

    /// The (lhs, rhs) DAG node ids this step consumes.
    pub fn nodes(&self) -> (usize, usize) {
        (self.lhs_node, self.rhs_node)
    }
}

/// Reusable, plan-agnostic scratch memory for [`CompiledPlan::run`]. Create
/// once per thread, hand back on every call; it grows to the largest plan it
/// has served and is never shrunk, so the steady state allocates nothing.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Liveness-packed arena holding intermediate (working-list) tensors.
    values: Vec<f32>,
    /// Canonicalized operand a (when a transform is needed).
    scratch_a: Vec<f32>,
    /// Canonicalized operand b.
    scratch_b: Vec<f32>,
    /// Raw kernel output, before `out_perm`.
    scratch_out: Vec<f32>,
    /// Ping-pong buffers for pre-sum chains.
    presum0: Vec<f32>,
    presum1: Vec<f32>,
    /// Packing panels for the cache-blocked GEMM path (see
    /// [`crate::exec::atom::PackBufs`]); empty when the selected kernel
    /// variant carries no packed GEMM or no step's shape engages it.
    pack_a: Vec<f32>,
    pack_b: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Total capacity currently held, in bytes.
    pub fn bytes(&self) -> usize {
        std::mem::size_of::<f32>()
            * (self.values.len()
                + self.scratch_a.len()
                + self.scratch_b.len()
                + self.scratch_out.len()
                + self.presum0.len()
                + self.presum1.len()
                + self.pack_a.len()
                + self.pack_b.len())
    }

    fn ensure(&mut self, plan: &CompiledPlan) {
        grow(&mut self.values, plan.values_len);
        grow(&mut self.scratch_a, plan.scratch_a_len);
        grow(&mut self.scratch_b, plan.scratch_b_len);
        grow(&mut self.scratch_out, plan.scratch_out_len);
        grow(&mut self.presum0, plan.presum_len);
        grow(&mut self.presum1, plan.presum_len);
        grow(&mut self.pack_a, plan.pack_a_len);
        grow(&mut self.pack_b, plan.pack_b_len);
    }
}

fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

/// Reusable scratch memory for **training** steps: a [`Workspace`] (whose
/// value arena doubles as the tape/cotangent arena — training and inference
/// share one allocation) plus the backward-only scratch buffers. Create one
/// per thread (layers own one; coordinator workers own one), hand it to
/// every [`CompiledPlan::train_forward`] / [`CompiledPlan::train_backward`]
/// pair; like the inference workspace it grows to the largest plan it has
/// served and the steady state allocates nothing.
///
/// The arena holds live tape state between a taped forward and its
/// backward. Every taped forward — and any mutable access to the inference
/// half via [`TrainWorkspace::base_mut`] — bumps the workspace epoch, which
/// invalidates previously issued tapes (their backward then fails with a
/// clear error instead of reading clobbered data).
#[derive(Debug)]
pub struct TrainWorkspace {
    /// Inference workspace; `base.values` is also the training arena.
    base: Workspace,
    /// Cotangent of operand a in canonical layout (backward kernels).
    scratch_da: Vec<f32>,
    /// Cotangent of operand b in canonical layout.
    scratch_db: Vec<f32>,
    /// Step-output cotangent permuted to raw kernel layout.
    scratch_dout: Vec<f32>,
    /// Bumped by every taped forward (and `base_mut`); tapes record the
    /// epoch they were produced under.
    epoch: u64,
    /// Process-unique workspace identity: tapes are bound to the workspace
    /// whose arena holds them, so a backward against a *different*
    /// workspace (even one at the same epoch) is rejected instead of
    /// silently replaying that workspace's resident tape.
    id: u64,
}

impl Default for TrainWorkspace {
    fn default() -> Self {
        TrainWorkspace::new()
    }
}

impl TrainWorkspace {
    // alloc-ok(fn): workspace construction is one-time warm-up, not replay.
    pub fn new() -> TrainWorkspace {
        static NEXT_ID: AtomicU64 = AtomicU64::new(1);
        TrainWorkspace {
            base: Workspace::new(),
            scratch_da: Vec::new(),
            scratch_db: Vec::new(),
            scratch_dout: Vec::new(),
            epoch: 0,
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Process-unique identity of this workspace (see
    /// [`crate::autodiff::PathAutodiff::backward_into`]).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The inference [`Workspace`] sharing this training workspace's arena.
    /// Taking it invalidates any outstanding tape (an inference run reuses
    /// — and clobbers — the tape's arena ranges).
    pub fn base_mut(&mut self) -> &mut Workspace {
        self.epoch = self.epoch.wrapping_add(1);
        &mut self.base
    }

    /// Epoch of the most recent taped forward (see
    /// [`crate::autodiff::PathAutodiff::forward_with_tape_into`]).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Invalidate any outstanding tape without running anything.
    pub fn invalidate(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
    }

    /// Total capacity currently held, in bytes.
    pub fn bytes(&self) -> usize {
        self.base.bytes()
            + std::mem::size_of::<f32>()
                * (self.scratch_da.len() + self.scratch_db.len() + self.scratch_dout.len())
    }

    fn ensure_train(&mut self, plan: &CompiledPlan, layout: &TrainLayout) {
        self.base.ensure(plan);
        grow(&mut self.base.values, layout.arena_len);
        grow(&mut self.scratch_da, plan.scratch_a_len);
        grow(&mut self.scratch_db, plan.scratch_b_len);
        grow(&mut self.scratch_dout, plan.scratch_out_len);
    }
}

fn is_identity(perm: &[usize]) -> bool {
    perm.iter().enumerate().all(|(i, &p)| i == p)
}

/// Compile-time arena allocator: assigns intermediates to value-arena ranges,
/// reusing (and coalescing) ranges whose producer is dead.
///
/// This is the *online* first-pass allocator (best fit over the current
/// free list). The training layout runs it once to trace the allocation
/// history, then re-places the traced live intervals offline (see
/// [`pack_intervals`]) and keeps whichever placement peaks lower.
struct ArenaAlloc {
    len: usize,
    free: Vec<Range<usize>>,
}

impl ArenaAlloc {
    // alloc-ok(fn): the arena allocator itself runs only at compile time.
    fn new() -> ArenaAlloc {
        ArenaAlloc {
            len: 0,
            free: Vec::new(),
        }
    }

    fn alloc(&mut self, size: usize) -> Range<usize> {
        // Best fit: the smallest free block that holds `size`.
        let mut best: Option<usize> = None;
        for (i, r) in self.free.iter().enumerate() {
            let cap = r.end - r.start;
            if cap >= size {
                let better = match best {
                    Some(b) => cap < self.free[b].end - self.free[b].start,
                    None => true,
                };
                if better {
                    best = Some(i);
                }
            }
        }
        if let Some(i) = best {
            let r = self.free.remove(i);
            if r.end - r.start > size {
                self.free.push(r.start + size..r.end);
            }
            return r.start..r.start + size;
        }
        let start = self.len;
        self.len += size;
        start..self.len
    }

    // alloc-ok(fn): the arena allocator itself runs only at compile time.
    fn free(&mut self, r: Range<usize>) {
        if r.start == r.end {
            return;
        }
        self.free.push(r);
        self.free.sort_by_key(|r| r.start);
        let mut merged: Vec<Range<usize>> = Vec::with_capacity(self.free.len());
        for r in self.free.drain(..) {
            match merged.last_mut() {
                Some(last) if last.end == r.start => last.end = r.end,
                _ => merged.push(r),
            }
        }
        self.free = merged;
    }
}

/// One entry of a traced arena-simulation history. Alloc ids number the
/// (non-empty) allocations in call order; an id with no matching `Free`
/// stays live to the end of the simulation.
#[derive(Debug, Clone, Copy)]
enum ArenaEvent {
    Alloc { size: usize },
    Free { id: usize },
}

/// The arena the training-layout simulation allocates against. The layout
/// is built in (up to) two passes over the *same* deterministic
/// simulation:
///
/// * `Trace` — online best-fit ([`ArenaAlloc`]) plus an event trace of the
///   allocation history, from which the static live interval of every
///   value/cotangent slot can be read off;
/// * `Replay` — the second pass serves the identical allocation sequence
///   from placements computed *offline* by [`pack_intervals`], which sees
///   all intervals at once instead of placing them first-come.
///
/// Frees in replay mode are no-ops: lifetime safety is already encoded in
/// the offline placement (two intervals may overlap in address space only
/// when their traced lifetimes are disjoint — exactly the "freed before
/// the output is placed" ordering the simulation emits).
enum Arena {
    Trace {
        inner: ArenaAlloc,
        events: Vec<ArenaEvent>,
        /// `(start, alloc id)` for live allocations; starts are unique
        /// while live under best-fit, so they key the free → id lookup.
        live: Vec<(usize, usize)>,
    },
    Replay {
        placements: Vec<Range<usize>>,
        next: usize,
        len: usize,
    },
}

impl Arena {
    // alloc-ok(fn): layout simulation runs only at compile time.
    fn trace() -> Arena {
        Arena::Trace {
            inner: ArenaAlloc::new(),
            events: Vec::new(),
            live: Vec::new(),
        }
    }

    // alloc-ok(fn): layout simulation runs only at compile time.
    fn alloc(&mut self, size: usize) -> Range<usize> {
        if size == 0 {
            // Empty ranges occupy no space and need no trace identity.
            return 0..0;
        }
        match self {
            Arena::Trace {
                inner,
                events,
                live,
            } => {
                let id = events
                    .iter()
                    .filter(|e| matches!(e, ArenaEvent::Alloc { .. }))
                    .count();
                events.push(ArenaEvent::Alloc { size });
                let r = inner.alloc(size);
                live.push((r.start, id));
                r
            }
            Arena::Replay {
                placements, next, ..
            } => {
                let r = placements[*next].clone();
                *next += 1;
                debug_assert_eq!(r.end - r.start, size);
                r
            }
        }
    }

    // alloc-ok(fn): layout simulation runs only at compile time.
    fn free(&mut self, r: Range<usize>) {
        if r.start == r.end {
            return;
        }
        if let Arena::Trace {
            inner,
            events,
            live,
        } = self
        {
            let pos = live
                .iter()
                .position(|&(start, _)| start == r.start)
                .expect("freed range was traced live");
            let (_, id) = live.swap_remove(pos);
            events.push(ArenaEvent::Free { id });
            inner.free(r);
        }
    }

    fn len(&self) -> usize {
        match self {
            Arena::Trace { inner, .. } => inner.len,
            Arena::Replay { len, .. } => *len,
        }
    }
}

/// Offline best-fit-decreasing placement over a traced allocation history:
/// every allocation becomes a rectangle (`size` × live interval
/// `[birth, death)` in event time), placed largest-first at the
/// tightest-fitting address gap among already-placed rectangles whose
/// lifetimes overlap. Returns the placements (indexed by alloc id) and the
/// peak arena length. Unlike the online pass — which must commit to an
/// offset the moment `alloc` is called — this sees the whole schedule, so
/// large late-living blocks no longer land on top of fragmented holes.
// alloc-ok(fn): offline packing runs once per (plan, policy) at compile time.
fn pack_intervals(events: &[ArenaEvent]) -> (Vec<Range<usize>>, usize) {
    let mut iv: Vec<(usize, usize, usize)> = Vec::new(); // (size, birth, death)
    for (t, e) in events.iter().enumerate() {
        match *e {
            ArenaEvent::Alloc { size } => iv.push((size, t, usize::MAX)),
            ArenaEvent::Free { id } => iv[id].2 = t,
        }
    }
    let mut order: Vec<usize> = (0..iv.len()).collect();
    order.sort_by(|&x, &y| iv[y].0.cmp(&iv[x].0).then(iv[x].1.cmp(&iv[y].1)));
    let mut placed: Vec<Range<usize>> = vec![0..0; iv.len()];
    let mut done: Vec<usize> = Vec::with_capacity(iv.len());
    let mut peak = 0usize;
    let mut busy: Vec<Range<usize>> = Vec::with_capacity(iv.len());
    for &id in &order {
        let (size, birth, death) = iv[id];
        // Address ranges already committed to lifetimes overlapping ours.
        busy.clear();
        busy.extend(
            done.iter()
                .filter(|&&o| iv[o].1 < death && birth < iv[o].2)
                .map(|&o| placed[o].clone()),
        );
        busy.sort_by_key(|r| r.start);
        // Best fit over the free gaps; fall back to first past the end.
        let mut best: Option<(usize, usize)> = None; // (gap, offset)
        let mut cursor = 0usize;
        for r in &busy {
            if r.start > cursor {
                let gap = r.start - cursor;
                if gap >= size && best.map_or(true, |(g, _)| gap < g) {
                    best = Some((gap, cursor));
                }
            }
            cursor = cursor.max(r.end);
        }
        let off = best.map_or(cursor, |(_, o)| o);
        placed[id] = off..off + size;
        peak = peak.max(off + size);
        done.push(id);
    }
    (placed, peak)
}

/// Reject plans whose shape arithmetic could overflow `usize` before the
/// lowering loop multiplies it unchecked. Per step, every internal product
/// the lowering computes (canonical buffer lengths, triple-table
/// capacities, raw output length) is bounded by `∏ dims[0] · ∏ dims[1]` —
/// per conv axis the output extent satisfies `ia + ib − 1 ≤ ia · ib` — so a
/// checked product per step, plus a checked running total with headroom for
/// the training arena (values + cotangents + input copies), covers the
/// layout computation. Degenerate huge dims surface a structured error here
/// instead of wrapping into a silently undersized arena.
fn check_dims_no_overflow(plan: &Plan) -> Result<()> {
    let prod = |dims: &[usize]| dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d));
    let mut total: usize = 0;
    for (k, step) in plan.steps.iter().enumerate() {
        let bound = prod(&step.sized.dims[0])
            .zip(prod(&step.sized.dims[1]))
            .and_then(|(a, b)| a.checked_mul(b))
            .ok_or_else(|| {
                anyhow!(
                    "step {k} of '{}': element-count product of {:?} × {:?} overflows \
                     usize; refusing to compile a layout from wrapped sizes",
                    plan.expr,
                    step.sized.dims[0],
                    step.sized.dims[1]
                )
            })?;
        total = total.checked_add(bound).ok_or_else(|| {
            anyhow!(
                "plan '{}': cumulative arena footprint overflows usize at step {k}",
                plan.expr
            )
        })?;
    }
    // Training holds, per node, at most one value and one cotangent slot,
    // and each step touches ≤ 3 node-sized buffers (two operands, one
    // output) — 6× the per-step bound total covers the peak.
    total.checked_mul(6).ok_or_else(|| {
        anyhow!(
            "plan '{}': training arena footprint (values + cotangents) overflows usize",
            plan.expr
        )
    })?;
    Ok(())
}

/// Largest intermediate produced while pre-summing `presum` axes (descending
/// order) out of a tensor of `dims`; 0 when no pre-summing happens.
// alloc-ok(fn): compile-time scratch sizing.
fn presum_chain_max(dims: &[usize], presum: &[usize]) -> usize {
    if presum.is_empty() {
        return 0;
    }
    let mut shape = dims.to_vec();
    let mut max = 0usize;
    for &ax in presum {
        shape.remove(ax);
        max = max.max(shape.iter().product::<usize>());
    }
    max
}

/// A [`Plan`] lowered into a sequence of fully-resolved steps plus a
/// liveness-based workspace layout. Compile once, run many — see the module
/// docs for ownership and invalidation rules. Cheap to share: wrap in an
/// [`Arc`] (the coordinator and layer caches do).
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    pub(crate) plan: Arc<Plan>,
    /// Execution options hoisted out of the per-call path: every run of this
    /// compiled entry uses one consistent backend.
    opts: ExecOptions,
    pub(crate) in_dims: Vec<Vec<usize>>,
    out_shape: Vec<usize>,
    /// Value-arena range and shape of the root intermediate (pre final_perm).
    pub(crate) root: Range<usize>,
    root_shape: Vec<usize>,
    /// Inverse of `plan.final_perm` (output cotangent → root layout).
    pub(crate) inv_final_perm: Option<Vec<usize>>,
    pub(crate) steps: Vec<CompiledStep>,
    pub(crate) values_len: usize,
    scratch_a_len: usize,
    scratch_b_len: usize,
    scratch_out_len: usize,
    presum_len: usize,
    /// GEMM packing-panel capacities (maxed over steps; zero when no step
    /// engages the packed path under the pinned kernel variant).
    pack_a_len: usize,
    pack_b_len: usize,
    /// Per-policy training layouts (StoreAll / Sqrt / None), built lazily
    /// and cached on the compiled entry so every [`crate::autodiff`] tape
    /// over it shares one layout.
    train: [OnceLock<Arc<TrainLayout>>; 3],
}

impl CompiledPlan {
    /// Lower a plan into a compiled program (clones the plan; use
    /// [`CompiledPlan::compile_arc`] when you already hold an `Arc`).
    pub fn compile(plan: &Plan) -> Result<CompiledPlan> {
        Self::compile_arc(Arc::new(plan.clone()))
    }

    /// Lower a plan into a compiled program.
    // alloc-ok(fn): lowering runs once per (expression, shapes); replays are
    // allocation-free.
    pub fn compile_arc(plan: Arc<Plan>) -> Result<CompiledPlan> {
        let n = plan.n_inputs;
        if n < 2 {
            return Err(anyhow!("compiled plans require at least 2 inputs"));
        }
        let ksteps = plan.steps.len();
        // Recover the working-list → DAG-node mapping.
        let mut working: Vec<usize> = (0..n).collect();
        let mut node_pairs: Vec<(usize, usize)> = Vec::with_capacity(ksteps);
        for step in &plan.steps {
            let (i, j) = (step.lhs, step.rhs);
            if i >= working.len() || j >= working.len() || i == j {
                return Err(anyhow!("invalid step indices ({}, {})", i, j));
            }
            node_pairs.push((working[i], working[j]));
            let (hi, lo) = if i > j { (i, j) } else { (j, i) };
            working.remove(hi);
            working.remove(lo);
            working.push(n + node_pairs.len() - 1);
        }
        if working.len() != 1 {
            return Err(anyhow!(
                "plan left {} operands on the working list",
                working.len()
            ));
        }
        let root_node = working[0];

        // Input shapes: every input node is consumed by exactly one step.
        let mut in_dims: Vec<Option<Vec<usize>>> = vec![None; n];
        for (k, step) in plan.steps.iter().enumerate() {
            let (l, r) = node_pairs[k];
            if l < n {
                in_dims[l] = Some(step.sized.dims[0].clone());
            }
            if r < n {
                in_dims[r] = Some(step.sized.dims[1].clone());
            }
        }
        let in_dims: Vec<Vec<usize>> = in_dims
            .into_iter()
            .enumerate()
            .map(|(i, d)| d.ok_or_else(|| anyhow!("input {i} is not consumed by any step")))
            .collect::<Result<_>>()?;

        // Shape-arithmetic overflow guard: everything below multiplies
        // extents unchecked, so degenerate huge dims must be rejected first.
        check_dims_no_overflow(&plan)?;

        // Liveness: last step at which each node is read.
        let mut last_use = vec![0usize; n + ksteps];
        for (k, &(l, r)) in node_pairs.iter().enumerate() {
            last_use[l] = k;
            last_use[r] = k;
        }

        // Lower each step; assign arena ranges with liveness-driven reuse.
        let mut arena = ArenaAlloc::new();
        let mut node_range: Vec<Option<Range<usize>>> = vec![None; n + ksteps];
        let mut steps: Vec<CompiledStep> = Vec::with_capacity(ksteps);
        let (mut sa, mut sb, mut so, mut sp) = (0usize, 0usize, 0usize, 0usize);
        let (mut pka, mut pkb) = (0usize, 0usize);
        for (k, step) in plan.steps.iter().enumerate() {
            let (l, r) = node_pairs[k];
            let atom = canonicalize(&step.sized, &step.moduli);
            let kernel = atom.kernel();
            let (a_len, b_len, raw_len) = atom.canonical_lens();
            sa = sa.max(a_len);
            sb = sb.max(b_len);
            so = so.max(raw_len);
            let (pa_len, pb_len) = atom.pack_lens(&kernel);
            pka = pka.max(pa_len);
            pkb = pkb.max(pb_len);
            sp = sp.max(presum_chain_max(&step.sized.dims[0], &atom.presum_a));
            sp = sp.max(presum_chain_max(&step.sized.dims[1], &atom.presum_b));

            let resolve = |node: usize, ranges: &[Option<Range<usize>>]| -> Result<Operand> {
                if node < n {
                    Ok(Operand::Input(node))
                } else {
                    ranges[node]
                        .clone()
                        .map(Operand::Value)
                        .ok_or_else(|| anyhow!("step {k} reads unproduced intermediate"))
                }
            };
            let lhs_src = resolve(l, &node_range)?;
            let rhs_src = resolve(r, &node_range)?;
            // Free dying operands *before* allocating the output: the output
            // is written only after all operand reads complete, so it may
            // safely reuse their arena space.
            for node in [l, r] {
                if node >= n && last_use[node] == k {
                    if let Some(dead) = node_range[node].take() {
                        arena.free(dead);
                    }
                }
            }
            let out_elems: usize = atom.out_shape.iter().product();
            debug_assert_eq!(out_elems, raw_len);
            let out = arena.alloc(out_elems);
            node_range[n + k] = Some(out.clone());
            let canon_a = canon_op(&step.sized.dims[0], &atom.presum_a, &atom.perm_a);
            let canon_b = canon_op(&step.sized.dims[1], &atom.presum_b, &atom.perm_b);
            let grad_a = grad_gather(&step.sized.dims[0], &atom.presum_a, &atom.perm_a);
            let grad_b = grad_gather(&step.sized.dims[1], &atom.presum_b, &atom.perm_b);
            steps.push(CompiledStep {
                lhs_node: l,
                rhs_node: r,
                lhs_src,
                rhs_src,
                canon_a,
                canon_b,
                out,
                out_identity: is_identity(&atom.out_perm),
                inv_out_perm: invert_perm(&atom.out_perm),
                grad_a,
                grad_b,
                atom,
                kernel,
            });
        }

        let root = node_range[root_node]
            .clone()
            .ok_or_else(|| anyhow!("root intermediate was never produced"))?;
        let root_shape = steps.last().expect("n >= 2 implies steps").atom.out_shape.clone();
        let out_shape: Vec<usize> = match &plan.final_perm {
            Some(p) => p.iter().map(|&ax| root_shape[ax]).collect(),
            None => root_shape.clone(),
        };
        let opts = ExecOptions {
            backend: plan.backend,
        };
        let inv_final_perm = plan.final_perm.as_ref().map(|p| invert_perm(p));
        let compiled = CompiledPlan {
            opts,
            in_dims,
            out_shape,
            root,
            root_shape,
            inv_final_perm,
            values_len: arena.len,
            scratch_a_len: sa,
            scratch_b_len: sb,
            scratch_out_len: so,
            presum_len: sp,
            pack_a_len: pka,
            pack_b_len: pkb,
            steps,
            plan,
            train: Default::default(),
        };
        // Debug/test builds statically verify every freshly lowered plan
        // (arena liveness, permutations, gathers, FLOP totals, kernel order
        // versions — see `crate::verify`). Release callers get the same
        // check on [`PlanCache`] insertion or on demand via
        // [`CompiledPlan::verify`].
        if cfg!(debug_assertions) {
            compiled
                .verify()
                .map_err(|e| anyhow!("freshly compiled plan failed verification: {e}"))?;
        }
        Ok(compiled)
    }

    // ---- accessors -------------------------------------------------------

    /// The plan this program was lowered from (costs, expression, report).
    pub fn plan(&self) -> &Arc<Plan> {
        &self.plan
    }

    /// Execution options hoisted onto the compiled entry.
    pub fn exec_options(&self) -> &ExecOptions {
        &self.opts
    }

    pub fn backend(&self) -> Backend {
        self.opts.backend
    }

    pub fn n_inputs(&self) -> usize {
        self.plan.n_inputs
    }

    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    pub fn step(&self, k: usize) -> &CompiledStep {
        &self.steps[k]
    }

    /// Input shapes this plan is specialized to.
    pub fn in_dims(&self) -> &[Vec<usize>] {
        &self.in_dims
    }

    /// Output shape of a run.
    pub fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }

    /// Overwrite one step's recorded accumulation-order version so tests
    /// can exercise the [`CompiledPlan::verify`] rejection path without
    /// depending on a real cross-version plan artifact.
    #[doc(hidden)]
    pub fn poison_kernel_order_version_for_tests(&mut self, step: usize, version: u32) {
        self.steps[step].kernel.order_version = version;
    }

    /// Peak workspace footprint (bytes) a run of this plan requires.
    pub fn workspace_bytes(&self) -> usize {
        std::mem::size_of::<f32>()
            * (self.values_len
                + self.scratch_a_len
                + self.scratch_b_len
                + self.scratch_out_len
                + 2 * self.presum_len
                + self.pack_a_len
                + self.pack_b_len)
    }

    // ---- execution -------------------------------------------------------

    fn validate(&self, inputs: &[&Tensor]) -> Result<()> {
        if inputs.len() != self.plan.n_inputs {
            return Err(anyhow!(
                "plan expects {} inputs, got {}",
                self.plan.n_inputs,
                inputs.len()
            ));
        }
        for (i, t) in inputs.iter().enumerate() {
            if t.shape() != &self.in_dims[i][..] {
                return Err(anyhow!(
                    "input {} has shape {:?} but the plan was compiled for {:?}; \
                     recompile for the new shapes (compiled plans are \
                     shape-specialized)",
                    i,
                    t.shape(),
                    self.in_dims[i]
                ));
            }
        }
        Ok(())
    }

    /// Run the compiled program, allocating a fresh output tensor. The
    /// workspace is grown (once) as needed and reused across calls.
    pub fn run(&self, inputs: &[&Tensor], ws: &mut Workspace) -> Result<Tensor> {
        let mut out = Tensor::zeros(&self.out_shape);
        self.run_into(inputs, ws, &mut out)?;
        Ok(out)
    }

    /// Run the compiled program, writing into a caller-provided output
    /// tensor of exactly [`CompiledPlan::out_shape`] — the allocation-free
    /// steady-state entry point (as long as `out` is not sharing storage
    /// with a clone, in which case copy-on-write duplicates it once).
    pub fn run_into(&self, inputs: &[&Tensor], ws: &mut Workspace, out: &mut Tensor) -> Result<()> {
        self.run_into_with(inputs, ws, out, &self.opts)
    }

    /// As [`CompiledPlan::run_into`] with an explicit backend override.
    pub fn run_into_with(
        &self,
        inputs: &[&Tensor],
        ws: &mut Workspace,
        out: &mut Tensor,
        opts: &ExecOptions,
    ) -> Result<()> {
        self.validate(inputs)?;
        if out.shape() != &self.out_shape[..] {
            return Err(anyhow!(
                "output tensor has shape {:?}, plan produces {:?}",
                out.shape(),
                self.out_shape
            ));
        }
        ws.ensure(self);
        // Pool for the canonicalization pre-pass (parallel permute/pre-sum).
        // Explicit thread counts resolve through the persistent per-size
        // registry, so replays never spawn threads (and never allocate).
        let sized;
        let canon_pool: Option<&Pool> = match opts.backend {
            Backend::Scalar => None,
            Backend::Parallel { threads: 0 } => Some(Pool::global()),
            Backend::Parallel { threads } => {
                sized = Pool::sized(threads);
                Some(sized.as_ref())
            }
        };
        let Workspace {
            values,
            scratch_a,
            scratch_b,
            scratch_out,
            presum0,
            presum1,
            pack_a,
            pack_b,
        } = ws;
        let mut packs = PackBufs {
            a: pack_a,
            b: pack_b,
        };

        for step in &self.steps {
            let (a_len, b_len, raw_len) = step.atom.canonical_lens();
            let a_src: &[f32] = match &step.lhs_src {
                Operand::Input(i) => inputs[*i].data(),
                Operand::Value(r) => &values[r.clone()],
            };
            let b_src: &[f32] = match &step.rhs_src {
                Operand::Input(i) => inputs[*i].data(),
                Operand::Value(r) => &values[r.clone()],
            };
            let a_canon = canonicalize_into(
                a_src,
                &step.canon_a,
                &mut scratch_a[..a_len],
                presum0,
                presum1,
                canon_pool,
            );
            let b_canon = canonicalize_into(
                b_src,
                &step.canon_b,
                &mut scratch_b[..b_len],
                presum0,
                presum1,
                canon_pool,
            );
            let av: &[f32] = if a_canon {
                &scratch_a[..a_len]
            } else {
                a_src
            };
            let bv: &[f32] = if b_canon {
                &scratch_b[..b_len]
            } else {
                b_src
            };
            for v in scratch_out[..raw_len].iter_mut() {
                *v = 0.0;
            }
            step.atom.forward_into(
                &step.kernel,
                av,
                bv,
                &mut scratch_out[..raw_len],
                &mut packs,
                opts,
            );
            // Raw kernel layout → working-list layout, into the value arena.
            let dst = &mut values[step.out.clone()];
            if step.out_identity {
                dst.copy_from_slice(&scratch_out[..raw_len]);
            } else {
                permute_into(
                    &scratch_out[..raw_len],
                    &step.atom.raw_out_dims,
                    &step.atom.out_perm,
                    dst,
                    canon_pool,
                );
            }
        }

        let root = &values[self.root.clone()];
        match &self.plan.final_perm {
            Some(p) => permute_into(root, &self.root_shape, p, out.data_mut(), canon_pool),
            None => out.data_mut().copy_from_slice(root),
        }
        Ok(())
    }
}

/// Pre-sum + permute one operand into `dst` using the workspace kernels.
/// Returns `false` when the source is already canonical (no pre-sums,
/// identity permutation) and can be read in place — the zero-copy fast
/// path. Allocation-free: every stage's shape was resolved at compile time.
fn canonicalize_into(
    src: &[f32],
    op: &CanonOp,
    dst: &mut [f32],
    presum0: &mut [f32],
    presum1: &mut [f32],
    pool: Option<&Pool>,
) -> bool {
    if op.identity {
        return false;
    }
    if op.sums.is_empty() {
        permute_into(src, &op.post_shape, &op.perm, dst, pool);
        return true;
    }
    // Pre-sum chain: ping-pong between the presum buffers, replicating the
    // axis-by-axis accumulation order of `Tensor::sum_axis` exactly.
    let mut in_p0 = false;
    let mut first = true;
    for (shape, ax) in &op.sums {
        let cur_len: usize = shape.iter().product();
        let next_len = cur_len / shape[*ax];
        if first {
            sum_axis_into(src, shape, *ax, &mut presum0[..next_len], pool);
            in_p0 = true;
            first = false;
        } else if in_p0 {
            sum_axis_into(&presum0[..cur_len], shape, *ax, &mut presum1[..next_len], pool);
            in_p0 = false;
        } else {
            sum_axis_into(&presum1[..cur_len], shape, *ax, &mut presum0[..next_len], pool);
            in_p0 = true;
        }
    }
    let post_len: usize = op.post_shape.iter().product();
    let summed: &[f32] = if in_p0 {
        &presum0[..post_len]
    } else {
        &presum1[..post_len]
    };
    if is_identity(&op.perm) {
        dst.copy_from_slice(summed);
    } else {
        permute_into(summed, &op.post_shape, &op.perm, dst, pool);
    }
    true
}

// ---------------------------------------------------------------------------
// Training engine: per-policy liveness layouts + allocation-free
// forward-with-tape / backward execution
// ---------------------------------------------------------------------------

/// Where one step's gradient contribution lands in the training arena.
#[derive(Debug, Clone)]
pub(crate) struct GradTarget {
    pub(crate) range: Range<usize>,
    /// First contribution for this node: gather-write. Otherwise the gather
    /// accumulates onto the resident cotangent (same elementwise result as
    /// the heap tape's `add_assign`).
    pub(crate) fresh: bool,
}

/// One forward (or recompute) step placement: which compiled step to run
/// and where its operands/output live in the arena at that point.
#[derive(Debug, Clone)]
pub(crate) struct TrainStepLoc {
    pub(crate) k: usize,
    pub(crate) a: Range<usize>,
    pub(crate) b: Range<usize>,
    pub(crate) out: Range<usize>,
}

/// One backward step: checkpoint-segment recomputes to replay first, then
/// the VJP with fully-resolved operand/cotangent/target ranges.
#[derive(Debug, Clone)]
pub(crate) struct TrainBwdStep {
    pub(crate) k: usize,
    pub(crate) recompute: Vec<TrainStepLoc>,
    pub(crate) a: Range<usize>,
    pub(crate) b: Range<usize>,
    /// Cotangent of this step's output (working-list layout).
    pub(crate) dnode: Range<usize>,
    pub(crate) da: GradTarget,
    pub(crate) db: GradTarget,
}

/// A training-mode liveness layout: arena slots for every input copy, tape
/// value (per the checkpoint policy, including the transient peaks of
/// recompute segments) and cotangent, plus the fully-resolved forward and
/// backward schedules. Built once per `(CompiledPlan, CkptPolicy)` by
/// [`CompiledPlan::train_layout`]; replaying it against a caller-held
/// [`TrainWorkspace`] performs zero steady-state heap allocations.
///
/// The layout is produced by *simulating* the heap tape's exact schedule —
/// stored forward under the policy's keep-set, then the backward with its
/// deterministic checkpoint-segment recomputes — against a compile-time
/// arena allocator, so every value/cotangent gets a range whose lifetime
/// matches the heap path's and whose space is reused as soon as its
/// occupant dies. The simulation runs twice: an online best-fit pass that
/// traces every allocation's live interval, then (when it packs tighter)
/// a replay against an offline best-fit-decreasing placement of those
/// intervals — so the shipped peak is never above the plain best-fit
/// allocator's. `arena_bytes` is therefore the training step's peak tape
/// footprint (the quantity the paper's Table 3 bounds), reported by
/// [`crate::autodiff::MemoryMeter`] as a high-water mark.
#[derive(Debug, Clone)]
pub struct TrainLayout {
    policy: CkptPolicy,
    pub(crate) input_ranges: Vec<Range<usize>>,
    pub(crate) fwd: Vec<TrainStepLoc>,
    /// Root value range (pre final_perm) — the taped output source.
    pub(crate) root: Range<usize>,
    /// Cotangent slot of the root (the backward's entry point).
    pub(crate) droot: Range<usize>,
    pub(crate) bwd: Vec<TrainBwdStep>,
    /// Cotangent ranges of the `n` inputs after the backward completes.
    pub(crate) input_grads: Vec<Range<usize>>,
    /// Arena high-water mark, in elements.
    pub(crate) arena_len: usize,
}

impl TrainLayout {
    /// Checkpoint policy this layout was built for.
    pub fn policy(&self) -> CkptPolicy {
        self.policy
    }

    /// Arena high-water mark in elements: the peak number of f32 slots live
    /// at any point of the forward+backward schedule.
    pub fn arena_elems(&self) -> usize {
        self.arena_len
    }

    /// Arena high-water mark in bytes — the peak tape memory of a training
    /// step under this policy.
    pub fn arena_bytes(&self) -> usize {
        self.arena_len * std::mem::size_of::<f32>()
    }
}

/// Recursively place the recompute of `node` (a step output) from its
/// nearest resident ancestors, appending the steps in execution order —
/// the compile-time mirror of the heap tape's `recompute`.
fn plan_recompute(
    plan: &CompiledPlan,
    node: usize,
    arena: &mut Arena,
    val_range: &mut [Option<Range<usize>>],
    out: &mut Vec<TrainStepLoc>,
) {
    let n = plan.plan.n_inputs;
    debug_assert!(node >= n, "input values stay resident for the whole tape");
    let k = node - n;
    let (l, r) = (plan.steps[k].lhs_node, plan.steps[k].rhs_node);
    for dep in [l, r] {
        if val_range[dep].is_none() {
            plan_recompute(plan, dep, arena, val_range, out);
        }
    }
    let a = val_range[l].clone().expect("recompute dep resident");
    let b = val_range[r].clone().expect("recompute dep resident");
    let o = arena.alloc(plan.node_elems(node));
    val_range[node] = Some(o.clone());
    out.push(TrainStepLoc { k, a, b, out: o });
}

/// Execute one compiled step against the training arena: canonicalize both
/// operands through the workspace kernels, run the forward kernels into the
/// raw scratch, then write the working-list-layout result into its arena
/// range. Mirrors the inference loop of [`CompiledPlan::run_into_with`]
/// exactly, so step outputs are bit-identical to it (and to the heap tape
/// this engine replaces).
#[allow(clippy::too_many_arguments)]
fn exec_arena_step(
    step: &CompiledStep,
    a_rng: &Range<usize>,
    b_rng: &Range<usize>,
    out_rng: &Range<usize>,
    values: &mut [f32],
    scratch_a: &mut [f32],
    scratch_b: &mut [f32],
    scratch_out: &mut [f32],
    presum0: &mut [f32],
    presum1: &mut [f32],
    packs: &mut PackBufs<'_>,
    pool: Option<&Pool>,
    opts: &ExecOptions,
) {
    let (a_len, b_len, raw_len) = step.atom.canonical_lens();
    let a_src = &values[a_rng.clone()];
    let b_src = &values[b_rng.clone()];
    let a_canon = canonicalize_into(
        a_src,
        &step.canon_a,
        &mut scratch_a[..a_len],
        presum0,
        presum1,
        pool,
    );
    let b_canon = canonicalize_into(
        b_src,
        &step.canon_b,
        &mut scratch_b[..b_len],
        presum0,
        presum1,
        pool,
    );
    let av: &[f32] = if a_canon { &scratch_a[..a_len] } else { a_src };
    let bv: &[f32] = if b_canon { &scratch_b[..b_len] } else { b_src };
    for v in scratch_out[..raw_len].iter_mut() {
        *v = 0.0;
    }
    step.atom.forward_into(
        &step.kernel,
        av,
        bv,
        &mut scratch_out[..raw_len],
        packs,
        opts,
    );
    // The output range may alias a just-freed operand range — safe because
    // every operand read completed into `scratch_out` above.
    let dst = &mut values[out_rng.clone()];
    if step.out_identity {
        dst.copy_from_slice(&scratch_out[..raw_len]);
    } else {
        permute_into(
            &scratch_out[..raw_len],
            &step.atom.raw_out_dims,
            &step.atom.out_perm,
            dst,
            pool,
        );
    }
}

impl CompiledPlan {
    /// Flat element count of a DAG node's value (inputs `0..n`, then step
    /// outputs in working-list layout).
    fn node_elems(&self, node: usize) -> usize {
        let n = self.plan.n_inputs;
        if node < n {
            self.in_dims[node].iter().product()
        } else {
            self.steps[node - n].atom.out_shape.iter().product()
        }
    }

    /// Is `node` read by any step ≥ `after`?
    fn node_needed_after(&self, node: usize, after: usize) -> bool {
        self.steps[after..]
            .iter()
            .any(|s| s.lhs_node == node || s.rhs_node == node)
    }

    /// The training-mode liveness layout for `policy`, built once and
    /// cached on the compiled entry (all tapes over this plan share it).
    pub fn train_layout(&self, policy: CkptPolicy) -> Arc<TrainLayout> {
        let slot = match policy {
            CkptPolicy::StoreAll => &self.train[0],
            CkptPolicy::Sqrt => &self.train[1],
            CkptPolicy::None => &self.train[2],
        };
        Arc::clone(slot.get_or_init(|| Arc::new(self.build_train_layout(policy))))
    }

    /// Build the training layout for `policy`: simulate once against the
    /// online best-fit arena while tracing the allocation history, re-place
    /// the traced live intervals offline ([`pack_intervals`]), and — when
    /// the offline placement peaks lower — replay the identical simulation
    /// against it. The returned layout therefore never peaks *above* the
    /// plain best-fit allocator, and `verify_train_layout` holds for it by
    /// the same lifetime argument either way.
    // alloc-ok(fn): layout construction runs once per (plan, policy) and is
    // cached; training replays are allocation-free.
    fn build_train_layout(&self, policy: CkptPolicy) -> TrainLayout {
        let mut arena = Arena::trace();
        let bestfit = self.simulate_train_layout(policy, &mut arena);
        let events = match arena {
            Arena::Trace { events, .. } => events,
            Arena::Replay { .. } => unreachable!("first pass always traces"),
        };
        let (placements, packed_len) = pack_intervals(&events);
        if packed_len >= bestfit.arena_len {
            return bestfit;
        }
        let mut replay = Arena::Replay {
            placements,
            next: 0,
            len: packed_len,
        };
        let packed = self.simulate_train_layout(policy, &mut replay);
        debug_assert!(packed.arena_len <= bestfit.arena_len);
        packed
    }

    /// The best-fit (first-pass, trace-mode) arena peak for `policy`, in
    /// elements — the bound [`CompiledPlan::train_layout`] is asserted
    /// never to exceed (exec/tests.rs and the hot-path bench compare it
    /// against the shipped layout's peak).
    pub(crate) fn train_layout_bestfit_elems(&self, policy: CkptPolicy) -> usize {
        let mut arena = Arena::trace();
        self.simulate_train_layout(policy, &mut arena).arena_len
    }

    /// Simulate the heap tape's forward+backward schedule under `policy`
    /// against a compile-time arena, recording every step's operand/output
    /// ranges (including recompute segments) and every cotangent's slot.
    /// Deterministic in `(plan, policy)`: both arena passes observe the
    /// identical alloc/free call sequence.
    // alloc-ok(fn): layout simulation runs once or twice per (plan, policy)
    // and is cached; training replays are allocation-free.
    fn simulate_train_layout(&self, policy: CkptPolicy, arena: &mut Arena) -> TrainLayout {
        let n = self.plan.n_inputs;
        let ksteps = self.steps.len();
        // Which step outputs the stored forward retains (identical to the
        // heap tape's keep-set so gradients stay bit-identical).
        let keep: Vec<bool> = match policy {
            CkptPolicy::StoreAll => vec![true; ksteps],
            CkptPolicy::None => vec![false; ksteps],
            CkptPolicy::Sqrt => {
                let seg = (ksteps as f64).sqrt().ceil() as usize;
                (0..ksteps).map(|k| seg != 0 && k % seg == seg - 1).collect()
            }
        };

        let mut val_range: Vec<Option<Range<usize>>> = vec![None; n + ksteps];
        let mut grad_range: Vec<Option<Range<usize>>> = vec![None; n + ksteps];

        // Inputs are copied into arena slots and stay resident for the
        // whole step (the backward reads them for VJPs and recomputes).
        let input_ranges: Vec<Range<usize>> = (0..n)
            .map(|i| {
                let r = arena.alloc(self.node_elems(i));
                val_range[i] = Some(r.clone());
                r
            })
            .collect();

        // Stored forward: place every step output; free non-kept operands
        // once no later forward step reads them. Dying operands are freed
        // *before* the output is placed — the kernels stage results in
        // scratch and write back only after all operand reads complete, so
        // the output may reuse their space.
        let mut fwd = Vec::with_capacity(ksteps);
        for k in 0..ksteps {
            let (l, r) = (self.steps[k].lhs_node, self.steps[k].rhs_node);
            let a = val_range[l].clone().expect("operand resident");
            let b = val_range[r].clone().expect("operand resident");
            for node in [l, r] {
                if node >= n && !keep[node - n] && !self.node_needed_after(node, k + 1) {
                    if let Some(dead) = val_range[node].take() {
                        arena.free(dead);
                    }
                }
            }
            let out = arena.alloc(self.node_elems(n + k));
            val_range[n + k] = Some(out.clone());
            fwd.push(TrainStepLoc { k, a, b, out });
        }
        let root_node = n + ksteps - 1;
        // Post-forward sweep: everything non-kept still resident (beyond
        // the root) is dropped before the backward begins.
        for k in 0..ksteps {
            let node = n + k;
            if node != root_node && !keep[k] {
                if let Some(dead) = val_range[node].take() {
                    arena.free(dead);
                }
            }
        }
        let root = val_range[root_node].clone().expect("root resident");

        // Backward schedule, steps in reverse. Per step: recompute missing
        // operands from the nearest checkpoints, consume the output
        // cotangent, free the output value, then place the operand
        // cotangents (which may reuse the just-freed space — the gathers
        // run only after the backward kernels finished reading).
        let droot = arena.alloc(self.node_elems(root_node));
        grad_range[root_node] = Some(droot.clone());
        let mut bwd = Vec::with_capacity(ksteps);
        for k in (0..ksteps).rev() {
            let (l, r) = (self.steps[k].lhs_node, self.steps[k].rhs_node);
            let mut recompute = Vec::new();
            for node in [l, r] {
                if val_range[node].is_none() {
                    plan_recompute(self, node, arena, &mut val_range, &mut recompute);
                }
            }
            let a = val_range[l].clone().expect("operand resident");
            let b = val_range[r].clone().expect("operand resident");
            let o = n + k;
            let dnode = grad_range[o].take().expect("cotangent for step output");
            arena.free(dnode.clone());
            if let Some(dead) = val_range[o].take() {
                arena.free(dead);
            }
            let da = match grad_range[l].clone() {
                Some(range) => GradTarget {
                    range,
                    fresh: false,
                },
                None => {
                    let range = arena.alloc(self.node_elems(l));
                    grad_range[l] = Some(range.clone());
                    GradTarget { range, fresh: true }
                }
            };
            let db = match grad_range[r].clone() {
                Some(range) => GradTarget {
                    range,
                    fresh: false,
                },
                None => {
                    let range = arena.alloc(self.node_elems(r));
                    grad_range[r] = Some(range.clone());
                    GradTarget { range, fresh: true }
                }
            };
            bwd.push(TrainBwdStep {
                k,
                recompute,
                a,
                b,
                dnode,
                da,
                db,
            });
        }
        let input_grads: Vec<Range<usize>> = (0..n)
            .map(|i| {
                // `compile_arc` rejects plans with unconsumed inputs, so by
                // construction every input received a cotangent above.
                grad_range[i]
                    .clone()
                    .expect("compile guarantees every input is consumed by a step")
            })
            .collect();
        TrainLayout {
            policy,
            input_ranges,
            fwd,
            root,
            droot,
            bwd,
            input_grads,
            arena_len: arena.len(),
        }
    }

    /// Run the taped forward of a training step: copy the inputs into their
    /// arena slots, execute every step into its tape range per the layout's
    /// schedule, and write the (final-permuted) output into `out`. Returns
    /// the workspace epoch identifying the tape this call left resident —
    /// [`CompiledPlan::train_backward`] consumes it. Allocation-free after
    /// workspace warm-up; results are bit-identical to the heap tape.
    pub fn train_forward(
        &self,
        layout: &TrainLayout,
        inputs: &[&Tensor],
        ws: &mut TrainWorkspace,
        out: &mut Tensor,
    ) -> Result<u64> {
        self.validate(inputs)?;
        if out.shape() != &self.out_shape[..] {
            return Err(anyhow!(
                "output tensor has shape {:?}, plan produces {:?}",
                out.shape(),
                self.out_shape
            ));
        }
        ws.ensure_train(self, layout);
        ws.epoch = ws.epoch.wrapping_add(1);
        let epoch = ws.epoch;
        let sized;
        let canon_pool: Option<&Pool> = match self.opts.backend {
            Backend::Scalar => None,
            Backend::Parallel { threads: 0 } => Some(Pool::global()),
            Backend::Parallel { threads } => {
                sized = Pool::sized(threads);
                Some(sized.as_ref())
            }
        };
        let TrainWorkspace { base, .. } = ws;
        let Workspace {
            values,
            scratch_a,
            scratch_b,
            scratch_out,
            presum0,
            presum1,
            pack_a,
            pack_b,
        } = base;
        let mut packs = PackBufs {
            a: pack_a,
            b: pack_b,
        };
        for (i, t) in inputs.iter().enumerate() {
            values[layout.input_ranges[i].clone()].copy_from_slice(t.data());
        }
        for loc in &layout.fwd {
            exec_arena_step(
                &self.steps[loc.k],
                &loc.a,
                &loc.b,
                &loc.out,
                values,
                scratch_a,
                scratch_b,
                scratch_out,
                presum0,
                presum1,
                &mut packs,
                canon_pool,
                &self.opts,
            );
        }
        let root = &values[layout.root.clone()];
        match &self.plan.final_perm {
            Some(p) => permute_into(root, &self.root_shape, p, out.data_mut(), canon_pool),
            None => out.data_mut().copy_from_slice(root),
        }
        Ok(epoch)
    }

    /// Run the backward of a taped training step: seed the root cotangent
    /// from `dout`, replay the layout's reverse schedule (recomputing
    /// checkpoint segments in place), and write ∂L/∂input into the
    /// caller-provided `grads` (one tensor per input, natural shapes).
    /// Allocation-free after workspace warm-up; gradients are bit-identical
    /// to the heap tape's.
    pub fn train_backward(
        &self,
        layout: &TrainLayout,
        dout: &Tensor,
        ws: &mut TrainWorkspace,
        grads: &mut [Tensor],
    ) -> Result<()> {
        if dout.shape() != &self.out_shape[..] {
            return Err(anyhow!(
                "output cotangent has shape {:?}, plan produces {:?}",
                dout.shape(),
                self.out_shape
            ));
        }
        if grads.len() != self.plan.n_inputs {
            return Err(anyhow!(
                "expected {} gradient tensors, got {}",
                self.plan.n_inputs,
                grads.len()
            ));
        }
        for (i, g) in grads.iter().enumerate() {
            if g.shape() != &self.in_dims[i][..] {
                return Err(anyhow!(
                    "gradient {} has shape {:?} but input {} has shape {:?}",
                    i,
                    g.shape(),
                    i,
                    self.in_dims[i]
                ));
            }
        }
        ws.ensure_train(self, layout);
        let sized;
        let canon_pool: Option<&Pool> = match self.opts.backend {
            Backend::Scalar => None,
            Backend::Parallel { threads: 0 } => Some(Pool::global()),
            Backend::Parallel { threads } => {
                sized = Pool::sized(threads);
                Some(sized.as_ref())
            }
        };
        let TrainWorkspace {
            base,
            scratch_da,
            scratch_db,
            scratch_dout,
            ..
        } = ws;
        let Workspace {
            values,
            scratch_a,
            scratch_b,
            scratch_out,
            presum0,
            presum1,
            pack_a,
            pack_b,
        } = base;
        let mut packs = PackBufs {
            a: pack_a,
            b: pack_b,
        };
        // Seed the root cotangent (undoing the final permutation).
        {
            let dst = &mut values[layout.droot.clone()];
            match &self.inv_final_perm {
                Some(inv) => permute_into(dout.data(), dout.shape(), inv, dst, canon_pool),
                None => dst.copy_from_slice(dout.data()),
            }
        }
        for bstep in &layout.bwd {
            for rloc in &bstep.recompute {
                exec_arena_step(
                    &self.steps[rloc.k],
                    &rloc.a,
                    &rloc.b,
                    &rloc.out,
                    values,
                    scratch_a,
                    scratch_b,
                    scratch_out,
                    presum0,
                    presum1,
                    &mut packs,
                    canon_pool,
                    &self.opts,
                );
            }
            let step = &self.steps[bstep.k];
            let (a_len, b_len, raw_len) = step.atom.canonical_lens();
            let a_src = &values[bstep.a.clone()];
            let b_src = &values[bstep.b.clone()];
            let a_canon = canonicalize_into(
                a_src,
                &step.canon_a,
                &mut scratch_a[..a_len],
                presum0,
                presum1,
                canon_pool,
            );
            let b_canon = canonicalize_into(
                b_src,
                &step.canon_b,
                &mut scratch_b[..b_len],
                presum0,
                presum1,
                canon_pool,
            );
            let d_src = &values[bstep.dnode.clone()];
            let dv: &[f32] = if step.out_identity {
                d_src
            } else {
                permute_into(
                    d_src,
                    &step.atom.out_shape,
                    &step.inv_out_perm,
                    &mut scratch_dout[..raw_len],
                    canon_pool,
                );
                &scratch_dout[..raw_len]
            };
            let av: &[f32] = if a_canon { &scratch_a[..a_len] } else { a_src };
            let bv: &[f32] = if b_canon { &scratch_b[..b_len] } else { b_src };
            for v in scratch_da[..a_len].iter_mut() {
                *v = 0.0;
            }
            for v in scratch_db[..b_len].iter_mut() {
                *v = 0.0;
            }
            step.atom.backward_into(
                &step.kernel,
                av,
                bv,
                dv,
                &mut scratch_da[..a_len],
                &mut scratch_db[..b_len],
                &mut packs,
                &self.opts,
            );
            // Un-canonicalize the operand cotangents straight into their
            // arena slots (the backward kernels finished every read of
            // `av`/`bv`/`dv` above, so targets may reuse freed space).
            gather_into(
                &scratch_da[..a_len],
                &step.grad_a.out_shape,
                &step.grad_a.strides,
                &mut values[bstep.da.range.clone()],
                !bstep.da.fresh,
                canon_pool,
            );
            gather_into(
                &scratch_db[..b_len],
                &step.grad_b.out_shape,
                &step.grad_b.strides,
                &mut values[bstep.db.range.clone()],
                !bstep.db.fresh,
                canon_pool,
            );
        }
        for (i, g) in grads.iter_mut().enumerate() {
            g.data_mut()
                .copy_from_slice(&values[layout.input_grads[i].clone()]);
        }
        Ok(())
    }

    /// One **fused training step**: taped forward immediately followed by
    /// its backward, with the tape consumed inside the call (the workspace
    /// epoch is bumped on entry and again on exit, so any
    /// [`crate::autodiff::TapeToken`] issued before — or observed during —
    /// this step is rejected by a later `backward_into` instead of
    /// replaying clobbered arena state).
    ///
    /// This is the per-segment executor of the coalesced training batches
    /// the coordinator forms ([`crate::autodiff::PathAutodiff::train_step_batch_into`]
    /// is the layer-level wrapper): it skips the token round-trip of the
    /// split `forward_with_tape` / `backward` API, and like those entry
    /// points it performs zero heap allocations after workspace warm-up and
    /// produces bit-identical outputs and gradients.
    pub fn train_step(
        &self,
        layout: &TrainLayout,
        inputs: &[&Tensor],
        dout: &Tensor,
        ws: &mut TrainWorkspace,
        out: &mut Tensor,
        grads: &mut [Tensor],
    ) -> Result<()> {
        self.train_forward(layout, inputs, ws, out)?;
        let result = self.train_backward(layout, dout, ws, grads);
        // Consume the tape even on a failed backward: a retry must re-run
        // the forward rather than read half-consumed arena state.
        ws.invalidate();
        result
    }
}

// ---------------------------------------------------------------------------
// Shared plan cache
// ---------------------------------------------------------------------------

/// Everything that affects a compiled plan's structure — the cache key for
/// [`PlanCache`]. Covers every [`PlanOptions`] field the planner's tree
/// selection depends on (`cost_cap` is keyed by its bit pattern, since
/// `f64` is not `Hash`/`Eq`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub expr: String,
    pub dims: Vec<Vec<usize>>,
    pub backend: Backend,
    pub strategy: Strategy,
    pub training: bool,
    pub conv_kinds: Option<Vec<ConvKind>>,
    /// `PlanOptions::cost_cap` as IEEE-754 bits (caps the per-step cost).
    pub cost_cap_bits: Option<u64>,
    /// `PlanOptions::max_dp_inputs` (flips Optimal to Greedy above it).
    pub max_dp_inputs: usize,
    /// Tuning-cache generation at key construction: the current global
    /// generation for `Strategy::Measured` (so calibration invalidates
    /// cached measured plans — post-calibration lookups miss and
    /// recompile against fresh measurements), `0` for every analytic
    /// strategy, whose selection never reads the tuning cache.
    pub tuning_generation: u64,
}

impl PlanKey {
    // alloc-ok(fn): cache-key construction happens per lookup, not per replay.
    fn new(expr: &str, dims: &[Vec<usize>], opts: &PlanOptions) -> PlanKey {
        PlanKey {
            expr: expr.to_string(),
            dims: dims.to_vec(),
            backend: opts.backend,
            strategy: opts.strategy,
            training: opts.training,
            conv_kinds: opts.conv_kinds.clone(),
            cost_cap_bits: opts.cost_cap.map(f64::to_bits),
            max_dp_inputs: opts.max_dp_inputs,
            tuning_generation: match opts.strategy {
                Strategy::Measured { .. } => crate::cost::tuning::generation(),
                _ => 0,
            },
        }
    }
}

/// Default entry bound for [`PlanCache`]: enough for every realistic layer
/// geometry mix while keeping worst-case ad-hoc traffic (client-controlled
/// shapes) from growing resident memory without bound.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 256;

/// A concurrent compile-once cache: coordinator workers (and any caller that
/// evaluates the same expression repeatedly) share compiled plans keyed by
/// [`PlanKey`]. Bounded: when full, the least-recently-used entry is
/// evicted, so client-controlled shape churn cannot grow memory without
/// limit.
#[derive(Debug)]
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, (Arc<CompiledPlan>, u64)>>,
    tick: AtomicUsize,
    capacity: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::with_capacity(DEFAULT_PLAN_CACHE_CAPACITY)
    }

    /// A cache holding at most `capacity` compiled plans (LRU-evicted).
    pub fn with_capacity(capacity: usize) -> PlanCache {
        PlanCache {
            map: Mutex::new(HashMap::new()),
            tick: AtomicUsize::new(0),
            capacity: capacity.max(1),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Fetch (or plan + compile) the program for `expr` at these shapes.
    pub fn get_or_compile(
        &self,
        expr: &str,
        dims: &[Vec<usize>],
        opts: &PlanOptions,
    ) -> Result<Arc<CompiledPlan>> {
        self.get_or_compile_with(PlanKey::new(expr, dims, opts), || {
            compile_expr(expr, dims, opts)
        })
    }

    /// As [`PlanCache::get_or_compile`] with an already-parsed spec, so the
    /// caller's parse is reused instead of re-parsing on a miss.
    pub fn get_or_compile_parsed(
        &self,
        expr: &str,
        spec: &EinsumSpec,
        dims: &[Vec<usize>],
        opts: &PlanOptions,
    ) -> Result<Arc<CompiledPlan>> {
        self.get_or_compile_with(PlanKey::new(expr, dims, opts), || {
            compile_spec(spec.clone(), dims, opts)
        })
    }

    fn get_or_compile_with(
        &self,
        key: PlanKey,
        compile: impl FnOnce() -> Result<CompiledPlan>,
    ) -> Result<Arc<CompiledPlan>> {
        let now = self.tick.fetch_add(1, Ordering::Relaxed) as u64;
        if let Some((hit, stamp)) = self.map.lock().unwrap().get_mut(&key) {
            *stamp = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(hit));
        }
        // Compile outside the lock: planning can be expensive, and two
        // racing compilers of the same key converge on whichever inserts
        // first.
        let compiled = Arc::new(compile()?);
        // Cached entries are replayed many times by many workers, so verify
        // them statically even in release builds (debug builds already
        // verified inside `compile_arc`; the check is idempotent).
        if !cfg!(debug_assertions) {
            compiled
                .verify()
                .map_err(|e| anyhow!("compiled plan failed verification: {e}"))?;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        if !map.contains_key(&key) && map.len() >= self.capacity {
            // Evict the least-recently-used entry.
            if let Some(oldest) = map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                map.remove(&oldest);
            }
        }
        let entry = map.entry(key).or_insert((compiled, now));
        entry.1 = now;
        Ok(Arc::clone(&entry.0))
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum number of entries retained.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Parse + size + plan + compile in one call (≥ 2 inputs; single-input
/// expressions have no pairwise path and go through
/// [`crate::exec::conv_einsum`] directly).
// alloc-ok(fn): one-shot parse + plan + compile entry point.
pub fn compile_expr(expr: &str, dims: &[Vec<usize>], opts: &PlanOptions) -> Result<CompiledPlan> {
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    compile_spec(spec, dims, opts)
}

/// As [`compile_expr`] starting from an already-parsed spec.
// alloc-ok(fn): one-shot plan + compile entry point.
pub fn compile_spec(
    spec: EinsumSpec,
    dims: &[Vec<usize>],
    opts: &PlanOptions,
) -> Result<CompiledPlan> {
    let sized = match &opts.conv_kinds {
        Some(kinds) => SizedSpec::with_kinds(spec, dims.to_vec(), kinds.clone()),
        None => SizedSpec::new(spec, dims.to_vec()),
    }
    .map_err(|e| anyhow!("{e}"))?;
    let plan = plan_with(&sized, opts).map_err(|e| anyhow!("{e}"))?;
    CompiledPlan::compile_arc(Arc::new(plan))
}
