//! Compile-once, run-many execution engine.
//!
//! The paper's thesis is that the evaluation *path* through a tensorial
//! convolution determines its cost — but in a training or serving loop the
//! same expression with the same shapes executes millions of times, and
//! re-discovering the path (parse → plan → canonicalize every atom →
//! allocate every intermediate) on each call wastes most of the win. This
//! module lowers a [`Plan`] **once** into a [`CompiledPlan`]:
//!
//! * every step carries its precomputed [`Atom`] (pre-sum axes, canonical
//!   permutations, conv triple tables) and [`AtomKernel`] (head/run/combined
//!   tables plus the step's selected SIMD microkernel,
//!   [`crate::kernels::StepKernel`]), so replays do zero canonicalization
//!   analysis;
//! * a liveness-based workspace layout assigns every intermediate a range in
//!   a value arena, reusing ranges as soon as their producer dies — the
//!   caller holds the [`Workspace`] and hands it back on every call, so the
//!   steady-state path performs **no heap allocations** after warm-up on
//!   *both* backends (the parallel backend dispatches to the persistent
//!   worker pool instead of spawning scoped threads; `bench_hotpath`
//!   asserts zero steady-state allocations for scalar and parallel alike);
//! * input canonicalization (permute / pre-sum) runs through the
//!   workspace-backed [`crate::tensor::permute_into`] /
//!   [`crate::tensor::sum_axis_into`] kernels, optionally fanned out over
//!   the worker pool — the previously single-threaded stretch of the hot
//!   path.
//!
//! # Workspace ownership
//!
//! A [`Workspace`] is plan-agnostic scratch capacity: it grows to fit
//! whatever plan runs against it and holds no results between calls, so one
//! workspace per thread serves any number of compiled plans (the
//! coordinator gives each worker one). It is `Send` but not shareable —
//! runs need `&mut`.
//!
//! # Invalidation
//!
//! A compiled plan is specialized to exact input shapes (and the backend /
//! strategy recorded at planning time). [`CompiledPlan::run`] rejects
//! mismatched shapes with an error telling the caller to recompile; layer
//! caches key compiled plans by `(batch, height, width)` and the shared
//! [`PlanCache`] keys them by [`PlanKey`] `(expr, dims, backend, strategy,
//! training, conv kinds)`.
//!
//! # Determinism
//!
//! Replays are bit-identical to a fresh [`crate::exec::conv_einsum`] call:
//! the canonicalization kernels replicate `Tensor::sum_axis` /
//! `Tensor::permute` accumulation orders exactly, and the step kernels are
//! the same code both paths execute.

use crate::einsum::{parse, ConvKind, EinsumSpec, SizedSpec};
use crate::exec::atom::{canonicalize, Atom, AtomKernel};
use crate::exec::{Backend, ExecOptions};
use crate::parallel::Pool;
use crate::planner::{plan_with, Plan, PlanOptions, Strategy};
use crate::tensor::{permute_into, sum_axis_into, Tensor};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Where a step operand's flat data lives at run time.
#[derive(Debug, Clone)]
enum Operand {
    /// Caller-provided input tensor `i`.
    Input(usize),
    /// Intermediate produced by an earlier step, at this value-arena range.
    Value(Range<usize>),
}

/// Fully-resolved canonicalization recipe for one operand: every pre-sum
/// stage's shape is precomputed, so replays do no shape bookkeeping (and no
/// allocation).
#[derive(Debug, Clone)]
struct CanonOp {
    /// (input shape, axis to sum) per pre-sum stage, in execution order.
    sums: Vec<(Vec<usize>, usize)>,
    /// Shape after all pre-sums (input to the permutation).
    post_shape: Vec<usize>,
    /// Canonical permutation (the atom's `perm_a`/`perm_b`).
    perm: Vec<usize>,
    /// No pre-sums and an identity permutation: read the source in place.
    identity: bool,
}

fn canon_op(dims: &[usize], presum: &[usize], perm: &[usize]) -> CanonOp {
    let mut shape = dims.to_vec();
    let mut sums = Vec::with_capacity(presum.len());
    for &ax in presum {
        sums.push((shape.clone(), ax));
        shape.remove(ax);
    }
    let identity = sums.is_empty() && is_identity(perm);
    CanonOp {
        sums,
        post_shape: shape,
        perm: perm.to_vec(),
        identity,
    }
}

/// One fully-resolved step of a compiled plan.
#[derive(Debug, Clone)]
pub struct CompiledStep {
    /// DAG node ids (inputs are `0..n`; step `k` produces node `n + k`).
    lhs_node: usize,
    rhs_node: usize,
    /// Run-time locations of the operands' flat data.
    lhs_src: Operand,
    rhs_src: Operand,
    /// Canonicalization recipes for the two operands.
    canon_a: CanonOp,
    canon_b: CanonOp,
    /// Value-arena range receiving this step's output (post `out_perm`).
    out: Range<usize>,
    /// Whether `atom.out_perm` is the identity (raw layout == working-list
    /// layout), precomputed so replays skip the per-run check.
    out_identity: bool,
    atom: Atom,
    kernel: AtomKernel,
}

impl CompiledStep {
    pub fn atom(&self) -> &Atom {
        &self.atom
    }

    pub fn kernel_tables(&self) -> &AtomKernel {
        &self.kernel
    }

    /// The (lhs, rhs) DAG node ids this step consumes.
    pub fn nodes(&self) -> (usize, usize) {
        (self.lhs_node, self.rhs_node)
    }
}

/// Reusable, plan-agnostic scratch memory for [`CompiledPlan::run`]. Create
/// once per thread, hand back on every call; it grows to the largest plan it
/// has served and is never shrunk, so the steady state allocates nothing.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Liveness-packed arena holding intermediate (working-list) tensors.
    values: Vec<f32>,
    /// Canonicalized operand a (when a transform is needed).
    scratch_a: Vec<f32>,
    /// Canonicalized operand b.
    scratch_b: Vec<f32>,
    /// Raw kernel output, before `out_perm`.
    scratch_out: Vec<f32>,
    /// Ping-pong buffers for pre-sum chains.
    presum0: Vec<f32>,
    presum1: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Total capacity currently held, in bytes.
    pub fn bytes(&self) -> usize {
        std::mem::size_of::<f32>()
            * (self.values.len()
                + self.scratch_a.len()
                + self.scratch_b.len()
                + self.scratch_out.len()
                + self.presum0.len()
                + self.presum1.len())
    }

    fn ensure(&mut self, plan: &CompiledPlan) {
        grow(&mut self.values, plan.values_len);
        grow(&mut self.scratch_a, plan.scratch_a_len);
        grow(&mut self.scratch_b, plan.scratch_b_len);
        grow(&mut self.scratch_out, plan.scratch_out_len);
        grow(&mut self.presum0, plan.presum_len);
        grow(&mut self.presum1, plan.presum_len);
    }
}

fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

fn is_identity(perm: &[usize]) -> bool {
    perm.iter().enumerate().all(|(i, &p)| i == p)
}

/// Compile-time arena allocator: assigns intermediates to value-arena ranges,
/// reusing (and coalescing) ranges whose producer is dead.
struct ArenaAlloc {
    len: usize,
    free: Vec<Range<usize>>,
}

impl ArenaAlloc {
    fn new() -> ArenaAlloc {
        ArenaAlloc {
            len: 0,
            free: Vec::new(),
        }
    }

    fn alloc(&mut self, size: usize) -> Range<usize> {
        // Best fit: the smallest free block that holds `size`.
        let mut best: Option<usize> = None;
        for (i, r) in self.free.iter().enumerate() {
            let cap = r.end - r.start;
            if cap >= size {
                let better = match best {
                    Some(b) => cap < self.free[b].end - self.free[b].start,
                    None => true,
                };
                if better {
                    best = Some(i);
                }
            }
        }
        if let Some(i) = best {
            let r = self.free.remove(i);
            if r.end - r.start > size {
                self.free.push(r.start + size..r.end);
            }
            return r.start..r.start + size;
        }
        let start = self.len;
        self.len += size;
        start..self.len
    }

    fn free(&mut self, r: Range<usize>) {
        if r.start == r.end {
            return;
        }
        self.free.push(r);
        self.free.sort_by_key(|r| r.start);
        let mut merged: Vec<Range<usize>> = Vec::with_capacity(self.free.len());
        for r in self.free.drain(..) {
            match merged.last_mut() {
                Some(last) if last.end == r.start => last.end = r.end,
                _ => merged.push(r),
            }
        }
        self.free = merged;
    }
}

/// Largest intermediate produced while pre-summing `presum` axes (descending
/// order) out of a tensor of `dims`; 0 when no pre-summing happens.
fn presum_chain_max(dims: &[usize], presum: &[usize]) -> usize {
    if presum.is_empty() {
        return 0;
    }
    let mut shape = dims.to_vec();
    let mut max = 0usize;
    for &ax in presum {
        shape.remove(ax);
        max = max.max(shape.iter().product::<usize>());
    }
    max
}

/// A [`Plan`] lowered into a sequence of fully-resolved steps plus a
/// liveness-based workspace layout. Compile once, run many — see the module
/// docs for ownership and invalidation rules. Cheap to share: wrap in an
/// [`Arc`] (the coordinator and layer caches do).
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    plan: Arc<Plan>,
    /// Execution options hoisted out of the per-call path: every run of this
    /// compiled entry uses one consistent backend.
    opts: ExecOptions,
    in_dims: Vec<Vec<usize>>,
    out_shape: Vec<usize>,
    /// Value-arena range and shape of the root intermediate (pre final_perm).
    root: Range<usize>,
    root_shape: Vec<usize>,
    steps: Vec<CompiledStep>,
    values_len: usize,
    scratch_a_len: usize,
    scratch_b_len: usize,
    scratch_out_len: usize,
    presum_len: usize,
}

impl CompiledPlan {
    /// Lower a plan into a compiled program (clones the plan; use
    /// [`CompiledPlan::compile_arc`] when you already hold an `Arc`).
    pub fn compile(plan: &Plan) -> Result<CompiledPlan> {
        Self::compile_arc(Arc::new(plan.clone()))
    }

    /// Lower a plan into a compiled program.
    pub fn compile_arc(plan: Arc<Plan>) -> Result<CompiledPlan> {
        let n = plan.n_inputs;
        if n < 2 {
            return Err(anyhow!("compiled plans require at least 2 inputs"));
        }
        let ksteps = plan.steps.len();
        // Recover the working-list → DAG-node mapping.
        let mut working: Vec<usize> = (0..n).collect();
        let mut node_pairs: Vec<(usize, usize)> = Vec::with_capacity(ksteps);
        for step in &plan.steps {
            let (i, j) = (step.lhs, step.rhs);
            if i >= working.len() || j >= working.len() || i == j {
                return Err(anyhow!("invalid step indices ({}, {})", i, j));
            }
            node_pairs.push((working[i], working[j]));
            let (hi, lo) = if i > j { (i, j) } else { (j, i) };
            working.remove(hi);
            working.remove(lo);
            working.push(n + node_pairs.len() - 1);
        }
        if working.len() != 1 {
            return Err(anyhow!(
                "plan left {} operands on the working list",
                working.len()
            ));
        }
        let root_node = working[0];

        // Input shapes: every input node is consumed by exactly one step.
        let mut in_dims: Vec<Option<Vec<usize>>> = vec![None; n];
        for (k, step) in plan.steps.iter().enumerate() {
            let (l, r) = node_pairs[k];
            if l < n {
                in_dims[l] = Some(step.sized.dims[0].clone());
            }
            if r < n {
                in_dims[r] = Some(step.sized.dims[1].clone());
            }
        }
        let in_dims: Vec<Vec<usize>> = in_dims
            .into_iter()
            .enumerate()
            .map(|(i, d)| d.ok_or_else(|| anyhow!("input {i} is not consumed by any step")))
            .collect::<Result<_>>()?;

        // Liveness: last step at which each node is read.
        let mut last_use = vec![0usize; n + ksteps];
        for (k, &(l, r)) in node_pairs.iter().enumerate() {
            last_use[l] = k;
            last_use[r] = k;
        }

        // Lower each step; assign arena ranges with liveness-driven reuse.
        let mut arena = ArenaAlloc::new();
        let mut node_range: Vec<Option<Range<usize>>> = vec![None; n + ksteps];
        let mut steps: Vec<CompiledStep> = Vec::with_capacity(ksteps);
        let (mut sa, mut sb, mut so, mut sp) = (0usize, 0usize, 0usize, 0usize);
        for (k, step) in plan.steps.iter().enumerate() {
            let (l, r) = node_pairs[k];
            let atom = canonicalize(&step.sized, &step.moduli);
            let kernel = atom.kernel();
            let (a_len, b_len, raw_len) = atom.canonical_lens();
            sa = sa.max(a_len);
            sb = sb.max(b_len);
            so = so.max(raw_len);
            sp = sp.max(presum_chain_max(&step.sized.dims[0], &atom.presum_a));
            sp = sp.max(presum_chain_max(&step.sized.dims[1], &atom.presum_b));

            let resolve = |node: usize, ranges: &[Option<Range<usize>>]| -> Result<Operand> {
                if node < n {
                    Ok(Operand::Input(node))
                } else {
                    ranges[node]
                        .clone()
                        .map(Operand::Value)
                        .ok_or_else(|| anyhow!("step {k} reads unproduced intermediate"))
                }
            };
            let lhs_src = resolve(l, &node_range)?;
            let rhs_src = resolve(r, &node_range)?;
            // Free dying operands *before* allocating the output: the output
            // is written only after all operand reads complete, so it may
            // safely reuse their arena space.
            for node in [l, r] {
                if node >= n && last_use[node] == k {
                    if let Some(dead) = node_range[node].take() {
                        arena.free(dead);
                    }
                }
            }
            let out_elems: usize = atom.out_shape.iter().product();
            debug_assert_eq!(out_elems, raw_len);
            let out = arena.alloc(out_elems);
            node_range[n + k] = Some(out.clone());
            let canon_a = canon_op(&step.sized.dims[0], &atom.presum_a, &atom.perm_a);
            let canon_b = canon_op(&step.sized.dims[1], &atom.presum_b, &atom.perm_b);
            steps.push(CompiledStep {
                lhs_node: l,
                rhs_node: r,
                lhs_src,
                rhs_src,
                canon_a,
                canon_b,
                out,
                out_identity: is_identity(&atom.out_perm),
                atom,
                kernel,
            });
        }

        let root = node_range[root_node]
            .clone()
            .ok_or_else(|| anyhow!("root intermediate was never produced"))?;
        let root_shape = steps.last().expect("n >= 2 implies steps").atom.out_shape.clone();
        let out_shape: Vec<usize> = match &plan.final_perm {
            Some(p) => p.iter().map(|&ax| root_shape[ax]).collect(),
            None => root_shape.clone(),
        };
        let opts = ExecOptions {
            backend: plan.backend,
        };
        Ok(CompiledPlan {
            opts,
            in_dims,
            out_shape,
            root,
            root_shape,
            values_len: arena.len,
            scratch_a_len: sa,
            scratch_b_len: sb,
            scratch_out_len: so,
            presum_len: sp,
            steps,
            plan,
        })
    }

    // ---- accessors -------------------------------------------------------

    /// The plan this program was lowered from (costs, expression, report).
    pub fn plan(&self) -> &Arc<Plan> {
        &self.plan
    }

    /// Execution options hoisted onto the compiled entry.
    pub fn exec_options(&self) -> &ExecOptions {
        &self.opts
    }

    pub fn backend(&self) -> Backend {
        self.opts.backend
    }

    pub fn n_inputs(&self) -> usize {
        self.plan.n_inputs
    }

    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    pub fn step(&self, k: usize) -> &CompiledStep {
        &self.steps[k]
    }

    /// Input shapes this plan is specialized to.
    pub fn in_dims(&self) -> &[Vec<usize>] {
        &self.in_dims
    }

    /// Output shape of a run.
    pub fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }

    /// Peak workspace footprint (bytes) a run of this plan requires.
    pub fn workspace_bytes(&self) -> usize {
        std::mem::size_of::<f32>()
            * (self.values_len
                + self.scratch_a_len
                + self.scratch_b_len
                + self.scratch_out_len
                + 2 * self.presum_len)
    }

    // ---- execution -------------------------------------------------------

    fn validate(&self, inputs: &[&Tensor]) -> Result<()> {
        if inputs.len() != self.plan.n_inputs {
            return Err(anyhow!(
                "plan expects {} inputs, got {}",
                self.plan.n_inputs,
                inputs.len()
            ));
        }
        for (i, t) in inputs.iter().enumerate() {
            if t.shape() != &self.in_dims[i][..] {
                return Err(anyhow!(
                    "input {} has shape {:?} but the plan was compiled for {:?}; \
                     recompile for the new shapes (compiled plans are \
                     shape-specialized)",
                    i,
                    t.shape(),
                    self.in_dims[i]
                ));
            }
        }
        Ok(())
    }

    /// Run the compiled program, allocating a fresh output tensor. The
    /// workspace is grown (once) as needed and reused across calls.
    pub fn run(&self, inputs: &[&Tensor], ws: &mut Workspace) -> Result<Tensor> {
        let mut out = Tensor::zeros(&self.out_shape);
        self.run_into(inputs, ws, &mut out)?;
        Ok(out)
    }

    /// Run the compiled program, writing into a caller-provided output
    /// tensor of exactly [`CompiledPlan::out_shape`] — the allocation-free
    /// steady-state entry point (as long as `out` is not sharing storage
    /// with a clone, in which case copy-on-write duplicates it once).
    pub fn run_into(&self, inputs: &[&Tensor], ws: &mut Workspace, out: &mut Tensor) -> Result<()> {
        self.run_into_with(inputs, ws, out, &self.opts)
    }

    /// As [`CompiledPlan::run_into`] with an explicit backend override.
    pub fn run_into_with(
        &self,
        inputs: &[&Tensor],
        ws: &mut Workspace,
        out: &mut Tensor,
        opts: &ExecOptions,
    ) -> Result<()> {
        self.validate(inputs)?;
        if out.shape() != &self.out_shape[..] {
            return Err(anyhow!(
                "output tensor has shape {:?}, plan produces {:?}",
                out.shape(),
                self.out_shape
            ));
        }
        ws.ensure(self);
        // Pool for the canonicalization pre-pass (parallel permute/pre-sum).
        // Explicit thread counts resolve through the persistent per-size
        // registry, so replays never spawn threads (and never allocate).
        let sized;
        let canon_pool: Option<&Pool> = match opts.backend {
            Backend::Scalar => None,
            Backend::Parallel { threads: 0 } => Some(Pool::global()),
            Backend::Parallel { threads } => {
                sized = Pool::sized(threads);
                Some(sized.as_ref())
            }
        };
        let Workspace {
            values,
            scratch_a,
            scratch_b,
            scratch_out,
            presum0,
            presum1,
        } = ws;

        for step in &self.steps {
            let (a_len, b_len, raw_len) = step.atom.canonical_lens();
            let a_src: &[f32] = match &step.lhs_src {
                Operand::Input(i) => inputs[*i].data(),
                Operand::Value(r) => &values[r.clone()],
            };
            let b_src: &[f32] = match &step.rhs_src {
                Operand::Input(i) => inputs[*i].data(),
                Operand::Value(r) => &values[r.clone()],
            };
            let a_canon = canonicalize_into(
                a_src,
                &step.canon_a,
                &mut scratch_a[..a_len],
                presum0,
                presum1,
                canon_pool,
            );
            let b_canon = canonicalize_into(
                b_src,
                &step.canon_b,
                &mut scratch_b[..b_len],
                presum0,
                presum1,
                canon_pool,
            );
            let av: &[f32] = if a_canon {
                &scratch_a[..a_len]
            } else {
                a_src
            };
            let bv: &[f32] = if b_canon {
                &scratch_b[..b_len]
            } else {
                b_src
            };
            for v in scratch_out[..raw_len].iter_mut() {
                *v = 0.0;
            }
            step.atom
                .forward_into(&step.kernel, av, bv, &mut scratch_out[..raw_len], opts);
            // Raw kernel layout → working-list layout, into the value arena.
            let dst = &mut values[step.out.clone()];
            if step.out_identity {
                dst.copy_from_slice(&scratch_out[..raw_len]);
            } else {
                permute_into(
                    &scratch_out[..raw_len],
                    &step.atom.raw_out_dims,
                    &step.atom.out_perm,
                    dst,
                    canon_pool,
                );
            }
        }

        let root = &values[self.root.clone()];
        match &self.plan.final_perm {
            Some(p) => permute_into(root, &self.root_shape, p, out.data_mut(), canon_pool),
            None => out.data_mut().copy_from_slice(root),
        }
        Ok(())
    }
}

/// Pre-sum + permute one operand into `dst` using the workspace kernels.
/// Returns `false` when the source is already canonical (no pre-sums,
/// identity permutation) and can be read in place — the zero-copy fast
/// path. Allocation-free: every stage's shape was resolved at compile time.
fn canonicalize_into(
    src: &[f32],
    op: &CanonOp,
    dst: &mut [f32],
    presum0: &mut [f32],
    presum1: &mut [f32],
    pool: Option<&Pool>,
) -> bool {
    if op.identity {
        return false;
    }
    if op.sums.is_empty() {
        permute_into(src, &op.post_shape, &op.perm, dst, pool);
        return true;
    }
    // Pre-sum chain: ping-pong between the presum buffers, replicating the
    // axis-by-axis accumulation order of `Tensor::sum_axis` exactly.
    let mut in_p0 = false;
    let mut first = true;
    for (shape, ax) in &op.sums {
        let cur_len: usize = shape.iter().product();
        let next_len = cur_len / shape[*ax];
        if first {
            sum_axis_into(src, shape, *ax, &mut presum0[..next_len], pool);
            in_p0 = true;
            first = false;
        } else if in_p0 {
            sum_axis_into(&presum0[..cur_len], shape, *ax, &mut presum1[..next_len], pool);
            in_p0 = false;
        } else {
            sum_axis_into(&presum1[..cur_len], shape, *ax, &mut presum0[..next_len], pool);
            in_p0 = true;
        }
    }
    let post_len: usize = op.post_shape.iter().product();
    let summed: &[f32] = if in_p0 {
        &presum0[..post_len]
    } else {
        &presum1[..post_len]
    };
    if is_identity(&op.perm) {
        dst.copy_from_slice(summed);
    } else {
        permute_into(summed, &op.post_shape, &op.perm, dst, pool);
    }
    true
}

// ---------------------------------------------------------------------------
// Shared plan cache
// ---------------------------------------------------------------------------

/// Everything that affects a compiled plan's structure — the cache key for
/// [`PlanCache`]. Covers every [`PlanOptions`] field the planner's tree
/// selection depends on (`cost_cap` is keyed by its bit pattern, since
/// `f64` is not `Hash`/`Eq`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub expr: String,
    pub dims: Vec<Vec<usize>>,
    pub backend: Backend,
    pub strategy: Strategy,
    pub training: bool,
    pub conv_kinds: Option<Vec<ConvKind>>,
    /// `PlanOptions::cost_cap` as IEEE-754 bits (caps the per-step cost).
    pub cost_cap_bits: Option<u64>,
    /// `PlanOptions::max_dp_inputs` (flips Optimal to Greedy above it).
    pub max_dp_inputs: usize,
}

impl PlanKey {
    fn new(expr: &str, dims: &[Vec<usize>], opts: &PlanOptions) -> PlanKey {
        PlanKey {
            expr: expr.to_string(),
            dims: dims.to_vec(),
            backend: opts.backend,
            strategy: opts.strategy,
            training: opts.training,
            conv_kinds: opts.conv_kinds.clone(),
            cost_cap_bits: opts.cost_cap.map(f64::to_bits),
            max_dp_inputs: opts.max_dp_inputs,
        }
    }
}

/// Default entry bound for [`PlanCache`]: enough for every realistic layer
/// geometry mix while keeping worst-case ad-hoc traffic (client-controlled
/// shapes) from growing resident memory without bound.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 256;

/// A concurrent compile-once cache: coordinator workers (and any caller that
/// evaluates the same expression repeatedly) share compiled plans keyed by
/// [`PlanKey`]. Bounded: when full, the least-recently-used entry is
/// evicted, so client-controlled shape churn cannot grow memory without
/// limit.
#[derive(Debug)]
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, (Arc<CompiledPlan>, u64)>>,
    tick: AtomicUsize,
    capacity: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::with_capacity(DEFAULT_PLAN_CACHE_CAPACITY)
    }

    /// A cache holding at most `capacity` compiled plans (LRU-evicted).
    pub fn with_capacity(capacity: usize) -> PlanCache {
        PlanCache {
            map: Mutex::new(HashMap::new()),
            tick: AtomicUsize::new(0),
            capacity: capacity.max(1),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Fetch (or plan + compile) the program for `expr` at these shapes.
    pub fn get_or_compile(
        &self,
        expr: &str,
        dims: &[Vec<usize>],
        opts: &PlanOptions,
    ) -> Result<Arc<CompiledPlan>> {
        self.get_or_compile_with(PlanKey::new(expr, dims, opts), || {
            compile_expr(expr, dims, opts)
        })
    }

    /// As [`PlanCache::get_or_compile`] with an already-parsed spec, so the
    /// caller's parse is reused instead of re-parsing on a miss.
    pub fn get_or_compile_parsed(
        &self,
        expr: &str,
        spec: &EinsumSpec,
        dims: &[Vec<usize>],
        opts: &PlanOptions,
    ) -> Result<Arc<CompiledPlan>> {
        self.get_or_compile_with(PlanKey::new(expr, dims, opts), || {
            compile_spec(spec.clone(), dims, opts)
        })
    }

    fn get_or_compile_with(
        &self,
        key: PlanKey,
        compile: impl FnOnce() -> Result<CompiledPlan>,
    ) -> Result<Arc<CompiledPlan>> {
        let now = self.tick.fetch_add(1, Ordering::Relaxed) as u64;
        if let Some((hit, stamp)) = self.map.lock().unwrap().get_mut(&key) {
            *stamp = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(hit));
        }
        // Compile outside the lock: planning can be expensive, and two
        // racing compilers of the same key converge on whichever inserts
        // first.
        let compiled = Arc::new(compile()?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        if !map.contains_key(&key) && map.len() >= self.capacity {
            // Evict the least-recently-used entry.
            if let Some(oldest) = map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                map.remove(&oldest);
            }
        }
        let entry = map.entry(key).or_insert((compiled, now));
        entry.1 = now;
        Ok(Arc::clone(&entry.0))
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum number of entries retained.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Parse + size + plan + compile in one call (≥ 2 inputs; single-input
/// expressions have no pairwise path and go through
/// [`crate::exec::conv_einsum`] directly).
pub fn compile_expr(expr: &str, dims: &[Vec<usize>], opts: &PlanOptions) -> Result<CompiledPlan> {
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    compile_spec(spec, dims, opts)
}

/// As [`compile_expr`] starting from an already-parsed spec.
pub fn compile_spec(
    spec: EinsumSpec,
    dims: &[Vec<usize>],
    opts: &PlanOptions,
) -> Result<CompiledPlan> {
    let sized = match &opts.conv_kinds {
        Some(kinds) => SizedSpec::with_kinds(spec, dims.to_vec(), kinds.clone()),
        None => SizedSpec::new(spec, dims.to_vec()),
    }
    .map_err(|e| anyhow!("{e}"))?;
    let plan = plan_with(&sized, opts).map_err(|e| anyhow!("{e}"))?;
    CompiledPlan::compile_arc(Arc::new(plan))
}
