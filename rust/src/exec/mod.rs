//! Execution of conv_einsum expressions (paper §3.1).
//!
//! * [`pairwise`] — evaluate a 2-input conv_einsum by canonicalizing it into
//!   the atomic grouped-convolution operation.
//! * [`pairwise_vjp`] — gradients of a pairwise op (the `g1`/`g2` of
//!   Appendix B).
//! * [`execute_path`] — run a multi-input expression along a
//!   [`crate::planner::Plan`]'s pairwise steps.
//! * [`conv_einsum`] — parse + plan (FLOPs-optimal) + execute in one call;
//!   the library's headline entry point.
//! * [`CompiledPlan`] / [`Workspace`] / [`PlanCache`] (module [`compiled`]) —
//!   the compile-once, run-many engine: a plan lowered once into
//!   fully-resolved steps with a liveness-based workspace layout, replayed
//!   allocation-free against a caller-held workspace. `execute_path` and
//!   `conv_einsum` are thin wrappers over compile+run.
//! * [`naive_eval`] — brute-force reference oracle (tests only).
//!
//! # Backend selection
//!
//! Every entry point executes atoms through a [`Backend`] carried by
//! [`ExecOptions`]:
//!
//! * [`Backend::Parallel`] (the default) dispatches independent
//!   per-`(group, output-row)` blocks of the atom across the persistent
//!   worker pool in [`crate::parallel`]; `threads == 0` uses the shared
//!   global pool, a positive count resolves to the persistent pool of that
//!   size ([`crate::parallel::Pool::sized`]).
//! * [`Backend::Scalar`] is the single-threaded executor, the baseline in
//!   `bench_hotpath`.
//!
//! Both backends run the same 8-lane microkernels ([`crate::kernels`]) in
//! the same per-row order, so their results are bit-identical on every
//! path.
//!
//! Plans record the backend chosen at planning time
//! ([`crate::planner::PlanOptions::backend`] → [`crate::planner::Plan::backend`]),
//! so [`execute_path`] and the autodiff tape replay with the same backend;
//! [`execute_path_with`] / [`pairwise_with`] override it per call.

pub mod atom;
pub mod compiled;
mod reference;

pub use atom::{canonicalize, conv_triples, Atom, AtomKernel, ConvAxis};
#[doc(hidden)]
pub use atom::force_conv_pack;
pub use compiled::{
    compile_expr, CompiledPlan, PlanCache, PlanKey, TrainLayout, TrainWorkspace, Workspace,
    DEFAULT_PLAN_CACHE_CAPACITY,
};
pub use reference::naive_eval;

use crate::einsum::{parse, SizedSpec};
use crate::planner::{plan_with, Plan, PlanOptions, Strategy};
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Which executor runs the atomic grouped convolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// The original single-threaded kernels.
    Scalar,
    /// Multi-threaded row-blocked kernels on the persistent worker pool.
    /// `threads == 0` means "use [`crate::parallel::Pool::global`]" and
    /// additionally falls back to the scalar kernels for atoms too small to
    /// amortize even a pool wake-up; a positive count forces the persistent
    /// pool of exactly that size ([`crate::parallel::Pool::sized`] —
    /// benchmarking / tests).
    Parallel { threads: usize },
}

impl Default for Backend {
    fn default() -> Self {
        Backend::Parallel { threads: 0 }
    }
}

/// Options controlling how pairwise atoms execute.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecOptions {
    pub backend: Backend,
}

impl ExecOptions {
    /// Single-threaded execution.
    pub fn scalar() -> ExecOptions {
        ExecOptions {
            backend: Backend::Scalar,
        }
    }

    /// Parallel execution (`threads == 0` → shared global pool).
    pub fn parallel(threads: usize) -> ExecOptions {
        ExecOptions {
            backend: Backend::Parallel { threads },
        }
    }
}

/// Evaluate a 2-input sized conv_einsum (default backend).
pub fn pairwise(sized: &SizedSpec, a: &Tensor, b: &Tensor) -> Tensor {
    pairwise_with(sized, a, b, &[], &ExecOptions::default())
}

/// As [`pairwise`], with explicit circular wrap moduli (one per entry of
/// `sized.spec.conv`; `None` = default). Needed for pairwise steps inside a
/// multi-way circular convolution, where the wrap length is the feature size
/// of the *whole* expression, not of this step.
pub fn pairwise_mod(
    sized: &SizedSpec,
    a: &Tensor,
    b: &Tensor,
    moduli: &[Option<usize>],
) -> Tensor {
    pairwise_with(sized, a, b, moduli, &ExecOptions::default())
}

/// As [`pairwise_mod`], with an explicit execution backend.
pub fn pairwise_with(
    sized: &SizedSpec,
    a: &Tensor,
    b: &Tensor,
    moduli: &[Option<usize>],
    opts: &ExecOptions,
) -> Tensor {
    let atom = canonicalize(sized, moduli);
    atom.execute_with(a, b, opts)
}

/// Gradients of a pairwise op: returns (∂L/∂a, ∂L/∂b) given ∂L/∂out.
pub fn pairwise_vjp(
    sized: &SizedSpec,
    a: &Tensor,
    b: &Tensor,
    dout: &Tensor,
) -> (Tensor, Tensor) {
    pairwise_vjp_with(sized, a, b, dout, &[], &ExecOptions::default())
}

/// As [`pairwise_vjp`] with explicit wrap moduli.
pub fn pairwise_vjp_mod(
    sized: &SizedSpec,
    a: &Tensor,
    b: &Tensor,
    dout: &Tensor,
    moduli: &[Option<usize>],
) -> (Tensor, Tensor) {
    pairwise_vjp_with(sized, a, b, dout, moduli, &ExecOptions::default())
}

/// As [`pairwise_vjp_mod`], with an explicit execution backend.
pub fn pairwise_vjp_with(
    sized: &SizedSpec,
    a: &Tensor,
    b: &Tensor,
    dout: &Tensor,
    moduli: &[Option<usize>],
    opts: &ExecOptions,
) -> (Tensor, Tensor) {
    let atom = canonicalize(sized, moduli);
    atom.vjp_with(a, b, dout, opts)
}

/// Execute a multi-input expression along a plan's pairwise steps, using the
/// backend recorded in the plan.
///
/// Mirrors opt-einsum's working-list semantics: each step consumes two
/// operands from the current list and appends the intermediate at the end;
/// the final remaining tensor (optionally permuted by the plan's
/// `final_perm`) is the result.
///
/// Internally the plan is lowered to a [`CompiledPlan`] and run once against
/// a throwaway [`Workspace`]. Callers evaluating the same plan repeatedly
/// (the compile-once, run-many regime) should compile once and hold the
/// workspace themselves — see the [`compiled`] module.
pub fn execute_path(plan: &Plan, inputs: &[&Tensor]) -> Result<Tensor> {
    execute_path_with(
        plan,
        inputs,
        &ExecOptions {
            backend: plan.backend,
        },
    )
}

/// As [`execute_path`], overriding the plan's backend.
pub fn execute_path_with(plan: &Plan, inputs: &[&Tensor], opts: &ExecOptions) -> Result<Tensor> {
    let compiled = CompiledPlan::compile(plan)?;
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(compiled.out_shape());
    compiled.run_into_with(inputs, &mut ws, &mut out, opts)?;
    Ok(out)
}

/// Parse, plan (FLOPs-optimal by default) and execute a conv_einsum string.
///
/// ```
/// use conv_einsum::{conv_einsum, Tensor};
/// use conv_einsum::util::rng::Rng;
/// let mut rng = Rng::new(0);
/// let x = Tensor::rand(&[2, 3, 8, 8], -1.0, 1.0, &mut rng);
/// let w = Tensor::rand(&[4, 3, 3, 3], -1.0, 1.0, &mut rng);
/// let y = conv_einsum("bshw,tshw->bthw|hw", &[&x, &w]).unwrap();
/// assert_eq!(y.shape(), &[2, 4, 8, 8]);
/// ```
pub fn conv_einsum(expr: &str, inputs: &[&Tensor]) -> Result<Tensor> {
    conv_einsum_with(expr, inputs, &PlanOptions::default())
}

/// As [`conv_einsum`] with explicit planning options (strategy, training
/// cost model, cost caps, convolution varieties, execution backend).
// alloc-ok(fn): one-shot parse + plan + execute wrapper; repeat callers use
// the compiled engine.
pub fn conv_einsum_with(expr: &str, inputs: &[&Tensor], opts: &PlanOptions) -> Result<Tensor> {
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    let dims: Vec<Vec<usize>> = inputs.iter().map(|t| t.shape().to_vec()).collect();
    let sized = match &opts.conv_kinds {
        Some(kinds) => SizedSpec::with_kinds(spec, dims, kinds.clone()),
        None => SizedSpec::new(spec, dims),
    }
    .map_err(|e| anyhow!("{e}"))?;
    if sized.spec.n_inputs() == 1 {
        // Degenerate: reductions/permutations of a single tensor.
        return Ok(single_input_eval(&sized, inputs[0]));
    }
    let plan = plan_with(&sized, opts).map_err(|e| anyhow!("{e}"))?;
    let compiled = CompiledPlan::compile_arc(Arc::new(plan))?;
    let mut ws = Workspace::new();
    compiled.run(inputs, &mut ws)
}

/// Evaluate a 1-input expression (self-sums + permutation).
// alloc-ok(fn): degenerate 1-input path, not part of the compiled hot loop.
pub fn single_input_eval(sized: &SizedSpec, x: &Tensor) -> Tensor {
    let spec = &sized.spec;
    let modes = &spec.inputs[0];
    // sum out modes not in output (descending axis order)
    let mut axes: Vec<usize> = modes
        .iter()
        .enumerate()
        .filter(|(_, m)| !spec.output.contains(m))
        .map(|(i, _)| i)
        .collect();
    axes.sort_unstable_by(|a, b| b.cmp(a));
    let mut t = x.clone();
    for ax in axes {
        t = t.sum_axis(ax);
    }
    let remaining: Vec<_> = modes
        .iter()
        .copied()
        .filter(|m| spec.output.contains(m))
        .collect();
    let perm: Vec<usize> = spec
        .output
        .iter()
        .map(|m| remaining.iter().position(|x| x == m).unwrap())
        .collect();
    t.permute(&perm)
}

/// Evaluate with the naive left-to-right strategy (the paper's baseline).
pub fn conv_einsum_ltr(expr: &str, inputs: &[&Tensor]) -> Result<Tensor> {
    conv_einsum_with(
        expr,
        inputs,
        &PlanOptions {
            strategy: Strategy::LeftToRight,
            ..PlanOptions::default()
        },
    )
}

#[cfg(test)]
mod tests;
