//! Execution of conv_einsum expressions (paper §3.1).
//!
//! * [`pairwise`] — evaluate a 2-input conv_einsum by canonicalizing it into
//!   the atomic grouped-convolution operation.
//! * [`pairwise_vjp`] — gradients of a pairwise op (the `g1`/`g2` of
//!   Appendix B).
//! * [`execute_path`] — run a multi-input expression along a
//!   [`crate::planner::Plan`]'s pairwise steps.
//! * [`conv_einsum`] — parse + plan (FLOPs-optimal) + execute in one call;
//!   the library's headline entry point.
//! * [`naive_eval`] — brute-force reference oracle (tests only).

pub mod atom;
mod reference;

pub use atom::{canonicalize, conv_triples, Atom, ConvAxis};
pub use reference::naive_eval;

use crate::einsum::{parse, SizedSpec};
use crate::planner::{plan_with, Plan, PlanOptions, Strategy};
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};

/// Evaluate a 2-input sized conv_einsum.
pub fn pairwise(sized: &SizedSpec, a: &Tensor, b: &Tensor) -> Tensor {
    pairwise_mod(sized, a, b, &[])
}

/// As [`pairwise`], with explicit circular wrap moduli (one per entry of
/// `sized.spec.conv`; `None` = default). Needed for pairwise steps inside a
/// multi-way circular convolution, where the wrap length is the feature size
/// of the *whole* expression, not of this step.
pub fn pairwise_mod(
    sized: &SizedSpec,
    a: &Tensor,
    b: &Tensor,
    moduli: &[Option<usize>],
) -> Tensor {
    let atom = canonicalize(sized, moduli);
    atom.execute(a, b)
}

/// Gradients of a pairwise op: returns (∂L/∂a, ∂L/∂b) given ∂L/∂out.
pub fn pairwise_vjp(
    sized: &SizedSpec,
    a: &Tensor,
    b: &Tensor,
    dout: &Tensor,
) -> (Tensor, Tensor) {
    pairwise_vjp_mod(sized, a, b, dout, &[])
}

/// As [`pairwise_vjp`] with explicit wrap moduli.
pub fn pairwise_vjp_mod(
    sized: &SizedSpec,
    a: &Tensor,
    b: &Tensor,
    dout: &Tensor,
    moduli: &[Option<usize>],
) -> (Tensor, Tensor) {
    let atom = canonicalize(sized, moduli);
    atom.vjp(a, b, dout)
}

/// Execute a multi-input expression along a plan's pairwise steps.
///
/// Mirrors opt-einsum's working-list semantics: each step consumes two
/// operands from the current list and appends the intermediate at the end;
/// the final remaining tensor (optionally permuted by the plan's
/// `final_perm`) is the result.
pub fn execute_path(plan: &Plan, inputs: &[&Tensor]) -> Result<Tensor> {
    if inputs.len() != plan.n_inputs {
        return Err(anyhow!(
            "plan expects {} inputs, got {}",
            plan.n_inputs,
            inputs.len()
        ));
    }
    // Single-input expressions: the plan has one pseudo-step with rhs=lhs
    // handled by the planner as an identity/reduction; here handle the
    // degenerate 1-input case by brute reduction via pairwise with a scalar.
    let mut working: Vec<Tensor> = inputs.iter().map(|t| (*t).clone()).collect();
    for step in &plan.steps {
        let (i, j) = (step.lhs, step.rhs);
        if i >= working.len() || j >= working.len() || i == j {
            return Err(anyhow!("invalid step indices ({}, {})", i, j));
        }
        let a = &working[i];
        let b = &working[j];
        debug_assert_eq!(a.shape(), &step.sized.dims[0][..], "step lhs shape");
        debug_assert_eq!(b.shape(), &step.sized.dims[1][..], "step rhs shape");
        let out = pairwise_mod(&step.sized, a, b, &step.moduli);
        // remove higher index first
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        working.remove(hi);
        working.remove(lo);
        working.push(out);
    }
    if working.len() != 1 {
        return Err(anyhow!(
            "plan left {} operands on the working list",
            working.len()
        ));
    }
    let mut result = working.pop().unwrap();
    if let Some(perm) = &plan.final_perm {
        result = result.permute(perm);
    }
    Ok(result)
}

/// Parse, plan (FLOPs-optimal by default) and execute a conv_einsum string.
///
/// ```
/// use conv_einsum::{conv_einsum, Tensor};
/// use conv_einsum::util::rng::Rng;
/// let mut rng = Rng::new(0);
/// let x = Tensor::rand(&[2, 3, 8, 8], -1.0, 1.0, &mut rng);
/// let w = Tensor::rand(&[4, 3, 3, 3], -1.0, 1.0, &mut rng);
/// let y = conv_einsum("bshw,tshw->bthw|hw", &[&x, &w]).unwrap();
/// assert_eq!(y.shape(), &[2, 4, 8, 8]);
/// ```
pub fn conv_einsum(expr: &str, inputs: &[&Tensor]) -> Result<Tensor> {
    conv_einsum_with(expr, inputs, &PlanOptions::default())
}

/// As [`conv_einsum`] with explicit planning options (strategy, training
/// cost model, cost caps, convolution varieties).
pub fn conv_einsum_with(expr: &str, inputs: &[&Tensor], opts: &PlanOptions) -> Result<Tensor> {
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    let dims: Vec<Vec<usize>> = inputs.iter().map(|t| t.shape().to_vec()).collect();
    let sized = match &opts.conv_kinds {
        Some(kinds) => SizedSpec::with_kinds(spec, dims, kinds.clone()),
        None => SizedSpec::new(spec, dims),
    }
    .map_err(|e| anyhow!("{e}"))?;
    if sized.spec.n_inputs() == 1 {
        // Degenerate: reductions/permutations of a single tensor.
        return Ok(single_input_eval(&sized, inputs[0]));
    }
    let plan = plan_with(&sized, opts).map_err(|e| anyhow!("{e}"))?;
    execute_path(&plan, inputs)
}

/// Evaluate a 1-input expression (self-sums + permutation).
pub fn single_input_eval(sized: &SizedSpec, x: &Tensor) -> Tensor {
    let spec = &sized.spec;
    let modes = &spec.inputs[0];
    // sum out modes not in output (descending axis order)
    let mut axes: Vec<usize> = modes
        .iter()
        .enumerate()
        .filter(|(_, m)| !spec.output.contains(m))
        .map(|(i, _)| i)
        .collect();
    axes.sort_unstable_by(|a, b| b.cmp(a));
    let mut t = x.clone();
    for ax in axes {
        t = t.sum_axis(ax);
    }
    let remaining: Vec<_> = modes
        .iter()
        .copied()
        .filter(|m| spec.output.contains(m))
        .collect();
    let perm: Vec<usize> = spec
        .output
        .iter()
        .map(|m| remaining.iter().position(|x| x == m).unwrap())
        .collect();
    t.permute(&perm)
}

/// Evaluate with the naive left-to-right strategy (the paper's baseline).
pub fn conv_einsum_ltr(expr: &str, inputs: &[&Tensor]) -> Result<Tensor> {
    conv_einsum_with(
        expr,
        inputs,
        &PlanOptions {
            strategy: Strategy::LeftToRight,
            ..PlanOptions::default()
        },
    )
}

#[cfg(test)]
mod tests;
