//! Canonicalization of a 2-input conv_einsum into the paper's *atomic
//! operation* (§3.1): a grouped N-way convolution
//!
//! ```text
//!   out[g, t, n, p⃗] = Σ_s Σ_{i⃗ ⊛ q⃗ = p⃗}  A[g, t, s, i⃗] · B[g, n, s, q⃗]
//! ```
//!
//! where `g` merges all batch-product modes, `t`/`n` merge the free modes of
//! each input, `s` merges all contraction modes, and `p⃗` ranges over the
//! convolution modes. Self-contraction modes (§3.1 case 5) are summed out in
//! pre-processing; same-type mode groups are merged by reshape (§3.1
//! "multiple letters with the same operation type").
//!
//! The convolution itself is driven by per-mode *triple tables*
//! `(ia, ib, p)` enumerating the index combinations that contribute, which
//! uniformly covers circular / same / valid / full varieties (and arbitrary
//! wrap moduli needed for pairwise steps inside a multi-way convolution).
//!
//! # Execution backends
//!
//! The atom is a family of independent GEMM-shaped blocks over
//! `(g, t, n)`: every output row `out[g,t,n,·]` (length `∏ I_oᶜ`) depends
//! only on row `A[g,t,·,·]`, the `B[g,·,·,·]` panel and the triple tables.
//! [`Atom::execute_with`] exploits this with [`crate::exec::Backend`]:
//!
//! * `Backend::Scalar` — the single-threaded loop nest;
//! * `Backend::Parallel` — the same kernels dispatched one output row (or,
//!   on the packed GEMM path, one microtile row band) per task across the
//!   persistent worker pool ([`crate::parallel::Pool`]).
//!
//! Both backends draw their inner loops from the process-selected
//! [`crate::kernels::dispatch::KernelTable`], pinned into the [`AtomKernel`]
//! holder when it is built. Pure contractions route per shape: a straight
//! scalar loop for tiny contraction depths (`s <` [`LANES`]), the variant's
//! packed cache-blocked GEMM ([`gemm_packed`] over workspace-owned
//! [`PackBufs`]) when [`crate::kernels::dispatch::GemmParams::engages`]
//! says the shape warrants packing, and the unblocked per-row dot/axpy
//! loops otherwise. Convolutions run the run-coalesced axpy kernels
//! ([`crate::kernels::axpy_run`]). Every routing predicate depends only on
//! the shape and the selected table — never on the backend — and parallel
//! partitions land on the same accumulation boundaries the serial loops
//! use, so scalar and parallel results are **bit-identical** on every path
//! for a fixed variant.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::einsum::{ConvKind, ModeId, SizedSpec};
use crate::exec::{Backend, ExecOptions};
use crate::kernels::dispatch::{self, GemmParams, KernelTable, Variant};
use crate::kernels::pack::{pack_a, pack_b, pack_conv_weights};
use crate::kernels::{axpy_run, dot_run, LANES, StepKernel};
use crate::parallel::Pool;
use crate::tensor::Tensor;

/// Test/bench override for the conv-atom panel engagement: 0 = auto
/// (the [`dispatch::ConvPackParams::engages`] predicate), 1 = never pack,
/// 2 = always pack (subject only to the workspace panel ceiling).
static FORCE_CONV_PACK: AtomicU8 = AtomicU8::new(0);

/// Pin the conv-atom panel routing (`None` restores the auto predicate).
///
/// Test/bench plumbing only (the packed-vs-unpacked sweep and the
/// bit-identity suite): the decision is captured per [`AtomKernel`] at
/// first use, so set this *before* compiling the plans it should affect
/// and restore it afterwards. Packing is a pure data-layout change —
/// forcing it either way never changes result bits for a fixed variant.
#[doc(hidden)]
pub fn force_conv_pack(v: Option<bool>) {
    let code = match v {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    FORCE_CONV_PACK.store(code, Ordering::Relaxed);
}

fn forced_conv_pack() -> Option<bool> {
    match FORCE_CONV_PACK.load(Ordering::Relaxed) {
        1 => Some(false),
        2 => Some(true),
        _ => None,
    }
}

/// One convolution axis of the atom.
#[derive(Debug, Clone)]
pub struct ConvAxis {
    pub mode: ModeId,
    pub ia: usize,
    pub ib: usize,
    pub out: usize,
    pub kind: ConvKind,
    /// Wrap modulus actually used (circular only).
    pub modulus: usize,
    /// Contributing index combinations.
    pub triples: Vec<(u32, u32, u32)>,
}

/// Build the triple table for one conv axis.
// alloc-ok(fn): table construction runs once per atom at compile/lowering time.
pub fn conv_triples(
    kind: ConvKind,
    ia: usize,
    ib: usize,
    modulus: Option<usize>,
) -> (usize, Vec<(u32, u32, u32)>) {
    let feat = ia.max(ib);
    let filt = ia.min(ib);
    let (out, p_of): (usize, Box<dyn Fn(usize) -> Option<usize>>) = match kind {
        ConvKind::Circular => {
            let p = modulus.unwrap_or(feat);
            let out = (ia + ib - 1).min(p);
            (out, Box::new(move |pf| Some(pf % p)))
        }
        ConvKind::Full => (ia + ib - 1, Box::new(Some)),
        ConvKind::Same => {
            let shift = (filt - 1) / 2;
            let out = feat;
            (
                out,
                Box::new(move |pf| {
                    let p = pf as isize - shift as isize;
                    (p >= 0 && (p as usize) < out).then(|| p as usize)
                }),
            )
        }
        ConvKind::Valid => {
            let shift = filt - 1;
            let out = feat - filt + 1;
            (
                out,
                Box::new(move |pf| {
                    let p = pf as isize - shift as isize;
                    (p >= 0 && (p as usize) < out).then(|| p as usize)
                }),
            )
        }
    };
    let mut triples = Vec::with_capacity(ia * ib);
    for a in 0..ia {
        for b in 0..ib {
            if let Some(p) = p_of(a + b) {
                triples.push((a as u32, b as u32, p as u32));
            }
        }
    }
    (out, triples)
}

/// The canonicalized atom for one pairwise conv_einsum.
#[derive(Debug, Clone)]
pub struct Atom {
    /// Axes of input 0 to sum out first (descending order).
    pub presum_a: Vec<usize>,
    /// Axes of input 1 to sum out first (descending order).
    pub presum_b: Vec<usize>,
    /// Permutation applied to (pre-summed) input 0: [batch, afree, contr, conv].
    pub perm_a: Vec<usize>,
    /// Permutation applied to (pre-summed) input 1: [batch, bfree, contr, conv].
    pub perm_b: Vec<usize>,
    /// Merged group sizes.
    pub g: usize,
    pub t: usize,
    pub n: usize,
    pub s: usize,
    /// Convolution axes in canonical order.
    pub conv: Vec<ConvAxis>,
    /// Raw output dims (mode-granular): [batch…, afree…, bfree…, conv…].
    pub raw_out_dims: Vec<usize>,
    /// Permutation from raw output to the requested output order.
    pub out_perm: Vec<usize>,
    /// Final output shape in requested order.
    pub out_shape: Vec<usize>,
}

/// Classify + canonicalize a 2-input sized spec.
///
/// `moduli` optionally overrides the circular wrap modulus per entry of
/// `spec.conv` (needed when this op is a step inside a multi-way convolution
/// whose feature size lives on a tensor not participating in this step).
// alloc-ok(fn): canonicalization analysis runs once per step at compile time.
pub fn canonicalize(sized: &SizedSpec, moduli: &[Option<usize>]) -> Atom {
    assert_eq!(sized.spec.n_inputs(), 2, "atom requires exactly 2 inputs");
    assert!(moduli.is_empty() || moduli.len() == sized.spec.conv.len());
    let spec = &sized.spec;
    let ma = &spec.inputs[0];
    let mb = &spec.inputs[1];
    let da = &sized.dims[0];
    let db = &sized.dims[1];

    let in_a = |m: ModeId| ma.contains(&m);
    let in_b = |m: ModeId| mb.contains(&m);
    let in_out = |m: ModeId| spec.output.contains(&m);
    let size_a = |m: ModeId| da[ma.iter().position(|&x| x == m).unwrap()];
    let size_b = |m: ModeId| db[mb.iter().position(|&x| x == m).unwrap()];

    // --- group the modes -------------------------------------------------
    let mut batch = Vec::new(); // in a & b & out (non-conv)
    let mut contr = Vec::new(); // in a & b, not out (non-conv)
    let mut afree = Vec::new(); // only a, in out (incl. 1-sided conv modes)
    let mut bfree = Vec::new();
    let mut presum_a_modes = Vec::new();
    let mut presum_b_modes = Vec::new();
    let mut convpair = Vec::new(); // conv modes in both inputs

    let mut seen = std::collections::HashSet::new();
    for &m in ma.iter().chain(mb.iter()) {
        if !seen.insert(m) {
            continue;
        }
        let conv = spec.is_conv(m);
        match (in_a(m), in_b(m)) {
            (true, true) if conv => convpair.push(m),
            (true, true) if in_out(m) => batch.push(m),
            (true, true) => contr.push(m),
            (true, false) if in_out(m) => afree.push(m),
            (true, false) => presum_a_modes.push(m),
            (false, true) if in_out(m) => bfree.push(m),
            (false, true) => presum_b_modes.push(m),
            (false, false) => unreachable!(),
        }
    }
    // Keep conv-pair order aligned with the pipe list.
    convpair.sort_by_key(|m| spec.conv.iter().position(|x| x == m).unwrap());

    // --- pre-sum axes ------------------------------------------------------
    let mut presum_a: Vec<usize> = presum_a_modes
        .iter()
        .map(|m| ma.iter().position(|x| x == m).unwrap())
        .collect();
    presum_a.sort_unstable_by(|x, y| y.cmp(x)); // descending
    let mut presum_b: Vec<usize> = presum_b_modes
        .iter()
        .map(|m| mb.iter().position(|x| x == m).unwrap())
        .collect();
    presum_b.sort_unstable_by(|x, y| y.cmp(x));

    // Mode lists after pre-sum.
    let ma2: Vec<ModeId> = ma
        .iter()
        .copied()
        .filter(|m| !presum_a_modes.contains(m))
        .collect();
    let mb2: Vec<ModeId> = mb
        .iter()
        .copied()
        .filter(|m| !presum_b_modes.contains(m))
        .collect();

    // --- canonical permutations -------------------------------------------
    let pos_a = |m: ModeId| ma2.iter().position(|&x| x == m).unwrap();
    let pos_b = |m: ModeId| mb2.iter().position(|&x| x == m).unwrap();
    let perm_a: Vec<usize> = batch
        .iter()
        .chain(afree.iter())
        .chain(contr.iter())
        .chain(convpair.iter())
        .map(|&m| pos_a(m))
        .collect();
    let perm_b: Vec<usize> = batch
        .iter()
        .chain(bfree.iter())
        .chain(contr.iter())
        .chain(convpair.iter())
        .map(|&m| pos_b(m))
        .collect();

    let g: usize = batch.iter().map(|&m| size_a(m)).product();
    let t: usize = afree.iter().map(|&m| size_a(m)).product();
    let n: usize = bfree.iter().map(|&m| size_b(m)).product();
    let s: usize = contr.iter().map(|&m| size_a(m)).product();

    // --- conv axes ----------------------------------------------------------
    let conv: Vec<ConvAxis> = convpair
        .iter()
        .map(|&m| {
            let pipe_idx = spec.conv.iter().position(|&x| x == m).unwrap();
            let kind = sized.conv_kinds[pipe_idx];
            let modulus = moduli.get(pipe_idx).copied().flatten();
            let ia = size_a(m);
            let ib = size_b(m);
            let (out, triples) = conv_triples(kind, ia, ib, modulus);
            ConvAxis {
                mode: m,
                ia,
                ib,
                out,
                kind,
                modulus: modulus.unwrap_or_else(|| ia.max(ib)),
                triples,
            }
        })
        .collect();

    // --- output layout -------------------------------------------------------
    // Raw order: batch…, afree…, bfree…, convpair…
    let raw_modes: Vec<ModeId> = batch
        .iter()
        .chain(afree.iter())
        .chain(bfree.iter())
        .chain(convpair.iter())
        .copied()
        .collect();
    let raw_out_dims: Vec<usize> = batch
        .iter()
        .map(|&m| size_a(m))
        .chain(afree.iter().map(|&m| size_a(m)))
        .chain(bfree.iter().map(|&m| size_b(m)))
        .chain(conv.iter().map(|c| c.out))
        .collect();

    debug_assert_eq!(raw_modes.len(), spec.output.len());
    let out_perm: Vec<usize> = spec
        .output
        .iter()
        .map(|m| raw_modes.iter().position(|x| x == m).unwrap())
        .collect();
    let out_shape: Vec<usize> = out_perm.iter().map(|&p| raw_out_dims[p]).collect();

    Atom {
        presum_a,
        presum_b,
        perm_a,
        perm_b,
        g,
        t,
        n,
        s,
        conv,
        raw_out_dims,
        out_perm,
        out_shape,
    }
}

/// Pre-sum + permute an input into canonical contiguous layout
/// `[G, F, S, conv…]` (F = t for input 0, n for input 1).
fn canonical_input(x: &Tensor, presum: &[usize], perm: &[usize]) -> Tensor {
    let mut x = x.clone();
    for &ax in presum {
        x = x.sum_axis(ax);
    }
    x.permute(perm)
}

/// Below this many forward multiplications, the auto backend
/// (`Backend::Parallel { threads: 0 }`) stays on the scalar kernels.
/// Dispatching to the persistent pool costs a condvar wake-up (~a µs), so
/// the bar is far lower than in the scoped-spawn era (tens of µs per
/// region) — but sub-µs atoms still are not worth waking workers for.
/// Either choice computes bit-identical results (the backends share their
/// microkernels). Explicit thread counts always take the parallel path
/// (benchmarks and tests rely on it).
const AUTO_PARALLEL_MIN_WORK: usize = 1 << 13;

/// Auto-backend threshold for contraction atoms when the selected variant
/// carries a packed GEMM: the microtile path clears small matmuls so fast
/// on one thread that pool dispatch only starts paying for itself a few
/// times later than on the unblocked kernels.
const AUTO_PARALLEL_MIN_WORK_GEMM: usize = 1 << 15;

/// Packing scratch for the cache-blocked GEMM path and the conv-atom
/// weight panels. On the hot replay paths these borrow the
/// `pack_a`/`pack_b` buffers owned by the workspace
/// ([`crate::exec::Workspace`] / the training arena), keeping
/// steady-state execution allocation-free; one-shot entry points pass
/// short-lived locals. Contraction atoms use both buffers for the GEMM
/// panels; conv atoms whose geometry engages the panel path (see
/// [`dispatch::ConvPackParams`]) use `b` for the consumption-ordered
/// weight panel. Empty slices are fine whenever [`Atom::pack_lens`]
/// returns zeros.
pub struct PackBufs<'a> {
    /// A-panel buffer (at least `pack_lens().0` floats).
    pub a: &'a mut [f32],
    /// B-panel buffer (at least `pack_lens().1` floats).
    pub b: &'a mut [f32],
}

/// Forward tables of a conv atom: the head-axes triple table, the
/// run-coalesced last axis, and the flattened `(head × run)`
/// consumption-order view the packed panel path and the run-structured
/// backward iterate.
#[derive(Debug, Clone)]
struct FwdTables {
    /// Head triples `(a_off, b_off, out_off)` over all conv axes but the
    /// last (in units of the last axis's extents).
    head: Vec<(u32, u32, u32)>,
    /// Last-axis runs `(ib, ia_start, p_start, len)`.
    runs: Vec<(u32, u32, u32, u32)>,
    /// `head × runs` flattened in consumption order:
    /// `(b_off, a_off, out_off, len)` with all offsets resolved into the
    /// conv blocks (`b_off = bo·lb + ib`, `a_off = ao·la + ia_start`,
    /// `out_off = po·lo + p_start`).
    flat: Vec<(u32, u32, u32, u32)>,
    /// The `b_off` column of `flat` — the gather list for
    /// [`pack_conv_weights`].
    boffs: Vec<u32>,
}

/// Resolved conv-panel packing decision for one [`AtomKernel`] (the conv
/// analogue of the resolved [`GemmParams`]): row width and total panel
/// footprint of the consumption-ordered weight panel.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ConvPack {
    /// Panel row width: `flat.len()` rounded up to a [`LANES`] multiple
    /// (the pad entries are zero weights, which the run loops skip).
    ne: usize,
    /// Total panel footprint: `g · n · s · ne` floats.
    panel_len: usize,
}

/// Kernel tables for one [`Atom`], built lazily and cached: the head-axes
/// triple table, the run-coalesced last conv axis, and the flattened
/// consumption-order view (see [`FwdTables`]) that drive both the forward
/// and the v3 run-structured backward kernels. A repeat caller
/// ([`crate::exec::CompiledPlan`], the autodiff tape) initializes the
/// tables at most once. The tables are unused for pure contractions (the
/// matmul kernels need none), but every holder carries the [`StepKernel`]
/// selected for the atom and the microkernel [`KernelTable`] (variant)
/// pinned at build time. Build the holder with [`Atom::kernel`].
#[derive(Debug, Clone)]
pub struct AtomKernel {
    fwd: std::sync::OnceLock<FwdTables>,
    /// Resolved conv-panel decision, captured at first use (compile time
    /// via [`Atom::pack_lens`]) so replays and workspace sizing agree.
    conv_pack: std::sync::OnceLock<Option<ConvPack>>,
    step: StepKernel,
    table: &'static KernelTable,
    /// GEMM parameters resolved for this atom's forward geometry when the
    /// holder was built: the table's static defaults with any per-geometry
    /// tuning from [`dispatch::resolved_gemm`] applied. `None` for conv
    /// atoms and GEMM-less variants. Embedding the resolved copy keeps
    /// replays free of registry lookups.
    gemm: Option<GemmParams>,
    /// [`crate::kernels::ACCUM_ORDER_VERSION`] captured when this holder
    /// was built; [`crate::exec::CompiledPlan::verify`] checks it so stale
    /// compiled steps cannot silently mix accumulation orders.
    pub(crate) order_version: u32,
}

impl AtomKernel {
    /// The microkernel family selected for this atom's inner loops.
    pub fn step(&self) -> StepKernel {
        self.step
    }

    /// The accumulation-order version this holder was built under.
    pub fn order_version(&self) -> u32 {
        self.order_version
    }

    /// The microkernel table pinned when this holder was built.
    pub fn table(&self) -> &'static KernelTable {
        self.table
    }

    /// The kernel variant pinned when this holder was built.
    /// [`crate::exec::CompiledPlan::verify`] compares it against the
    /// process selection so a plan never replays under a different
    /// accumulation order than it was pinned to.
    pub fn variant(&self) -> Variant {
        self.table.variant
    }

    /// The GEMM parameters resolved for this atom (static defaults or the
    /// per-geometry tuned override captured at build time).
    pub fn gemm(&self) -> Option<GemmParams> {
        self.gemm
    }

    /// Forward tables (head triples, last-axis runs, flattened
    /// consumption-order view); conv atoms only.
    // alloc-ok(fn): built at most once per holder (cached in the OnceLock),
    // at compile time on every workspace-backed path (pack_lens forces it).
    fn fwd_tables(&self, atom: &Atom) -> &FwdTables {
        self.fwd.get_or_init(|| {
            let (head, runs) = atom.head_and_runs();
            let last = atom.conv.last().unwrap();
            let (la, lb, lo) = (last.ia as u32, last.ib as u32, last.out as u32);
            let mut flat = Vec::with_capacity(head.len() * runs.len());
            for &(ao, bo, poo) in &head {
                for &(ib, ia0, p0, len) in &runs {
                    flat.push((bo * lb + ib, ao * la + ia0, poo * lo + p0, len));
                }
            }
            let boffs = flat.iter().map(|&(boff, ..)| boff).collect();
            FwdTables {
                head,
                runs,
                flat,
                boffs,
            }
        })
    }

    /// The conv-panel decision for this holder (always `None` for pure
    /// contractions). Resolved once — from the [`dispatch::ConvPackParams`]
    /// engagement predicate, or a [`force_conv_pack`] override — and
    /// cached, so execution and [`Atom::pack_lens`] workspace sizing can
    /// never disagree. Tiny geometries (below the predicate's FLOP floor)
    /// short-circuit to the plain run loops here.
    pub(crate) fn conv_pack(&self, atom: &Atom) -> Option<ConvPack> {
        *self.conv_pack.get_or_init(|| {
            if atom.conv.is_empty() {
                return None;
            }
            let entries = self.fwd_tables(atom).flat.len();
            if entries == 0 {
                return None;
            }
            let ne = (entries + LANES - 1) / LANES * LANES;
            let panel_len = atom
                .g
                .saturating_mul(atom.n)
                .saturating_mul(atom.s)
                .saturating_mul(ne);
            let cp = dispatch::conv_pack_params(self.table);
            let engaged = match forced_conv_pack() {
                Some(true) => panel_len <= cp.max_panel,
                Some(false) => false,
                None => cp.engages(atom.flop_estimate(), atom.t, panel_len),
            };
            engaged.then_some(ConvPack { ne, panel_len })
        })
    }
}

impl Atom {
    /// Estimated forward multiplications: G·T·N·S·∏(Iₐᶜ·I_bᶜ).
    fn flop_estimate(&self) -> usize {
        let (pa, pb, _) = self.conv_sizes();
        self.g
            .saturating_mul(self.t)
            .saturating_mul(self.n)
            .saturating_mul(self.s)
            .saturating_mul(pa)
            .saturating_mul(pb)
    }

    /// Total elements across the conv axes of input a / input b / output.
    pub fn conv_sizes(&self) -> (usize, usize, usize) {
        let pa: usize = self.conv.iter().map(|c| c.ia).product();
        let pb: usize = self.conv.iter().map(|c| c.ib).product();
        let po: usize = self.conv.iter().map(|c| c.out).product();
        (pa, pb, po)
    }

    /// Flat lengths of (canonical input a, canonical input b, raw kernel
    /// output) — the buffer sizes a workspace-backed execution needs.
    pub fn canonical_lens(&self) -> (usize, usize, usize) {
        let (pa, pb, po) = self.conv_sizes();
        (
            self.g * self.t * self.s * pa,
            self.g * self.n * self.s * pb,
            self.g * self.t * self.n * po,
        )
    }

    /// Packing-buffer lengths `(pack_a_len, pack_b_len)` this atom may
    /// need under `kernel`. For pure contractions these size the
    /// cache-blocked GEMM panels, as the elementwise max over the three
    /// matmul orientations the atom can run — forward
    /// `C(t×n) += A(t×s)·B(n×s)ᵀ`, backward `da(t×s) += D(t×n)·B(n×s)` and
    /// `db(n×s) += Dᵀ(n×t)·A(t×s)` — counting only orientations whose shape
    /// actually engages the packed path (the `+ LANES` term bounds the
    /// microtile row rounding for any `mr <= LANES`). For conv atoms the
    /// B length sizes the consumption-ordered weight panel when the
    /// geometry engages it (see [`dispatch::ConvPackParams`]), zero
    /// otherwise. Uses the holder's *resolved* parameters, so tuned
    /// per-geometry overrides and the cached panel decision size the
    /// scratch consistently with execution.
    pub fn pack_lens(&self, kernel: &AtomKernel) -> (usize, usize) {
        if !self.conv.is_empty() {
            return match kernel.conv_pack(self) {
                Some(cp) => (0, cp.panel_len),
                None => (0, 0),
            };
        }
        let gp = match kernel.gemm {
            Some(gp) => gp,
            None => return (0, 0),
        };
        let (t, n, s) = (self.t, self.n, self.s);
        // (rows m, output columns, contraction depth) per orientation.
        let shapes = [(t, n, s), (t, s, n), (n, s, t)];
        let mut a_len = 0usize;
        let mut b_len = 0usize;
        for (m, ncols, k) in shapes {
            if !gp.engages(m, ncols, k) {
                continue;
            }
            let kc = gp.kc.min(k);
            a_len = a_len.max((m + LANES) * kc);
            b_len = b_len.max((ncols / gp.nr) * gp.nr * kc);
        }
        (a_len, b_len)
    }

    /// Create the (lazily-populated) kernel-table holder for this atom
    /// against the process-selected microkernel variant, carrying the
    /// per-step microkernel selection. Holding one per compiled step —
    /// instead of rebuilding tables on every execution — is what makes
    /// [`crate::exec::CompiledPlan`] replays cheap.
    pub fn kernel(&self) -> AtomKernel {
        self.kernel_for(dispatch::selected())
    }

    /// Create the holder against an explicit microkernel table (per-variant
    /// test/bench plumbing; normal callers use [`Atom::kernel`]).
    pub fn kernel_for(&self, table: &'static KernelTable) -> AtomKernel {
        let gemm = if self.conv.is_empty() {
            dispatch::resolved_gemm(table, self.t, self.n, self.s)
        } else {
            None
        };
        AtomKernel {
            fwd: std::sync::OnceLock::new(),
            conv_pack: std::sync::OnceLock::new(),
            step: self.select_kernel(),
            table,
            gemm,
            order_version: crate::kernels::ACCUM_ORDER_VERSION,
        }
    }

    /// Select the microkernel family for this atom's inner loops: pure
    /// contractions run matmuls ([`StepKernel::MatmulDot8`], upgraded per
    /// shape to the packed GEMM at execution time); convolutions pick the
    /// wide (8-lane blocked) axpy when the last conv axis can produce runs
    /// long enough to fill a lane block, and the narrow (block-setup-free,
    /// bit-identical) variant otherwise. Run length on the last axis is
    /// bounded by `min(Iₐ, I_out)` — unit-stride `(ia, p)` successions
    /// cannot outrun either extent.
    pub fn select_kernel(&self) -> StepKernel {
        match self.conv.last() {
            None => StepKernel::MatmulDot8,
            Some(c) => {
                if c.ia.min(c.out) >= LANES {
                    StepKernel::ConvRunsWide
                } else {
                    StepKernel::ConvRunsNarrow
                }
            }
        }
    }

    /// §Perf: cross-product triples for all conv axes *except the last*, plus the
    /// last axis lowered into contiguous runs — for a fixed filter tap `ib`,
    /// consecutive feature indices `ia` map to consecutive outputs `p`, so
    /// the innermost loop becomes a vectorizable axpy over slices instead of
    /// per-element gather/scatter.
    // alloc-ok(fn): built at most once per atom (cached in the OnceLock).
    fn head_and_runs(&self) -> (Vec<(u32, u32, u32)>, Vec<(u32, u32, u32, u32)>) {
        debug_assert!(!self.conv.is_empty());
        let head_axes = &self.conv[..self.conv.len() - 1];
        let mut head: Vec<(u32, u32, u32)> = vec![(0, 0, 0)];
        for c in head_axes {
            let mut next = Vec::with_capacity(head.len() * c.triples.len());
            for &(ao, bo, po) in &head {
                for &(ia, ib, p) in &c.triples {
                    next.push((
                        ao * c.ia as u32 + ia,
                        bo * c.ib as u32 + ib,
                        po * c.out as u32 + p,
                    ));
                }
            }
            head = next;
        }
        // Coalesce the last axis triples into (ib, ia_start, p_start, len)
        // runs: group by ib, then merge unit-stride (ia, p) successions.
        let last = self.conv.last().unwrap();
        let mut by_ib: Vec<Vec<(u32, u32)>> = vec![Vec::new(); last.ib];
        for &(ia, ib, p) in &last.triples {
            by_ib[ib as usize].push((ia, p));
        }
        let mut runs: Vec<(u32, u32, u32, u32)> = Vec::new();
        for (ib, mut pairs) in by_ib.into_iter().enumerate() {
            pairs.sort_unstable();
            let mut i = 0;
            while i < pairs.len() {
                let (ia0, p0) = pairs[i];
                let mut len = 1u32;
                while i + (len as usize) < pairs.len() {
                    let (ia, p) = pairs[i + len as usize];
                    if ia == ia0 + len && p == p0 + len {
                        len += 1;
                    } else {
                        break;
                    }
                }
                runs.push((ib as u32, ia0, p0, len));
                i += len as usize;
            }
        }
        (head, runs)
    }

    /// The auto-backend work threshold for this atom under `kernel`'s
    /// variant (see [`AUTO_PARALLEL_MIN_WORK`] / the GEMM-specific bar).
    fn auto_parallel_min_work(&self, kernel: &AtomKernel) -> usize {
        if self.conv.is_empty() && kernel.gemm.is_some() {
            AUTO_PARALLEL_MIN_WORK_GEMM
        } else {
            AUTO_PARALLEL_MIN_WORK
        }
    }

    /// Execute the atom: `out = f(a, b)` (default backend).
    pub fn execute(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.execute_with(a, b, &ExecOptions::default())
    }

    /// Execute the atom with an explicit backend (tables computed on the
    /// fly; repeat callers should precompute them with [`Atom::kernel`] and
    /// use [`Atom::execute_with_kernel`]).
    pub fn execute_with(&self, a: &Tensor, b: &Tensor, opts: &ExecOptions) -> Tensor {
        self.execute_with_kernel(&self.kernel(), a, b, opts)
    }

    /// Execute the atom with precomputed kernel tables.
    // alloc-ok(fn): one-shot entry point; the hot path is `forward_into`
    // through a caller-held workspace.
    pub fn execute_with_kernel(
        &self,
        kernel: &AtomKernel,
        a: &Tensor,
        b: &Tensor,
        opts: &ExecOptions,
    ) -> Tensor {
        let ac = canonical_input(a, &self.presum_a, &self.perm_a);
        let bc = canonical_input(b, &self.presum_b, &self.perm_b);
        let (a_len, b_len, out_len) = self.canonical_lens();
        debug_assert_eq!(ac.len(), a_len);
        debug_assert_eq!(bc.len(), b_len);
        let av = ac.data();
        let bv = bc.data();
        let mut out = vec![0.0f32; out_len];
        let (pa_len, pb_len) = self.pack_lens(kernel);
        let mut pack_a_buf = vec![0.0f32; pa_len];
        let mut pack_b_buf = vec![0.0f32; pb_len];
        let mut packs = PackBufs {
            a: &mut pack_a_buf,
            b: &mut pack_b_buf,
        };
        self.forward_into(kernel, av, bv, &mut out, &mut packs, opts);
        Tensor::from_vec(&[out_len], out)
            .reshape(&self.raw_out_dims)
            .permute(&self.out_perm)
    }

    /// Run the forward kernels on pre-canonicalized flat inputs, writing
    /// into `out` (which the caller must have zeroed), honouring the
    /// backend. `packs` supplies the packing scratch for the cache-blocked
    /// GEMM path (see [`Atom::pack_lens`]; empty slices are fine when the
    /// lengths are zero). This is the workspace-level entry point used by
    /// [`crate::exec::CompiledPlan`].
    pub fn forward_into(
        &self,
        kernel: &AtomKernel,
        av: &[f32],
        bv: &[f32],
        out: &mut [f32],
        packs: &mut PackBufs<'_>,
        opts: &ExecOptions,
    ) {
        match opts.backend {
            Backend::Scalar => self.forward_impl(kernel, av, bv, out, packs, None),
            Backend::Parallel { threads }
                if threads == 0 && self.flop_estimate() < self.auto_parallel_min_work(kernel) =>
            {
                self.forward_impl(kernel, av, bv, out, packs, None)
            }
            Backend::Parallel { threads } => {
                let sized;
                let pool: &Pool = if threads == 0 {
                    Pool::global()
                } else {
                    sized = Pool::sized(threads);
                    sized.as_ref()
                };
                self.forward_impl(kernel, av, bv, out, packs, Some(pool));
            }
        }
    }

    /// The forward kernels, serial (`pool: None`) or row-parallel. The
    /// backends share one routing decision and one set of microkernels, and
    /// parallel partitions coincide with serial accumulation boundaries
    /// (one output row per task on the unblocked paths, one microtile row
    /// band on the packed GEMM path), so results are bit-identical per
    /// element either way.
    fn forward_impl(
        &self,
        kernel: &AtomKernel,
        av: &[f32],
        bv: &[f32],
        out: &mut [f32],
        packs: &mut PackBufs<'_>,
        pool: Option<&Pool>,
    ) {
        let (pa, pb, po) = self.conv_sizes();
        let (g, t, n, s) = (self.g, self.t, self.n, self.s);
        let table = kernel.table;
        if self.conv.is_empty() {
            // Pure contraction/batch/outer: out[g,t,n] = Σ_s A[g,t,s]·B[g,n,s].
            if s < LANES {
                // Tiny-K short-circuit: a straight unfused scalar loop in
                // every variant. Bit-identical to the v1 dot8 order (whose
                // lane blocks are empty below LANES and whose tail is this
                // exact sequential sum), and cheaper than re-entering a
                // blocked kernel that can never fill a lane.
                match pool {
                    Some(pool) => pool.run_chunks(out, n, |row, crow| {
                        let ti = row % t;
                        let gi = row / t;
                        let arow = &av[(gi * t + ti) * s..(gi * t + ti + 1) * s];
                        let b_g = &bv[gi * n * s..(gi + 1) * n * s];
                        for (ni, c) in crow.iter_mut().enumerate() {
                            let brow = &b_g[ni * s..(ni + 1) * s];
                            let mut acc = 0.0f32;
                            for (x, y) in arow.iter().zip(brow) {
                                acc += x * y;
                            }
                            *c += acc;
                        }
                    }),
                    None => {
                        for gi in 0..g {
                            let a_g = &av[gi * t * s..(gi + 1) * t * s];
                            let b_g = &bv[gi * n * s..(gi + 1) * n * s];
                            let o_g = &mut out[gi * t * n..(gi + 1) * t * n];
                            for ti in 0..t {
                                let arow = &a_g[ti * s..(ti + 1) * s];
                                let crow = &mut o_g[ti * n..(ti + 1) * n];
                                for (ni, c) in crow.iter_mut().enumerate() {
                                    let brow = &b_g[ni * s..(ni + 1) * s];
                                    let mut acc = 0.0f32;
                                    for (x, y) in arow.iter().zip(brow) {
                                        acc += x * y;
                                    }
                                    *c += acc;
                                }
                            }
                        }
                    }
                }
            } else if let Some(gp) = kernel.gemm.filter(|gp| gp.engages(t, n, s)) {
                // Packed cache-blocked GEMM per group.
                for gi in 0..g {
                    let a_g = &av[gi * t * s..(gi + 1) * t * s];
                    let b_g = &bv[gi * n * s..(gi + 1) * n * s];
                    let o_g = &mut out[gi * t * n..(gi + 1) * t * n];
                    gemm_packed(&gp, a_g, s, 1, b_g, 1, s, o_g, t, n, s, packs, pool);
                }
            } else {
                // Unblocked per-row fallback: one dot per output element.
                match pool {
                    Some(pool) => pool.run_chunks(out, n, |row, crow| {
                        let ti = row % t;
                        let gi = row / t;
                        let arow = &av[(gi * t + ti) * s..(gi * t + ti + 1) * s];
                        let b_g = &bv[gi * n * s..(gi + 1) * n * s];
                        for (ni, c) in crow.iter_mut().enumerate() {
                            *c += (table.dot)(arow, &b_g[ni * s..(ni + 1) * s]);
                        }
                    }),
                    None => {
                        for gi in 0..g {
                            let a_g = &av[gi * t * s..(gi + 1) * t * s];
                            let b_g = &bv[gi * n * s..(gi + 1) * n * s];
                            let o_g = &mut out[gi * t * n..(gi + 1) * t * n];
                            matmul_nt(table, a_g, b_g, o_g, t, n, s);
                        }
                    }
                }
            }
        } else {
            // §Perf run-coalesced kernel: head axes via triple table, last
            // axis as contiguous axpy runs (see EXPERIMENTS.md §Perf/L3)
            // through the step-selected microkernel. When the geometry
            // engages the conv panel, the weights are first gathered into a
            // consumption-ordered panel (one padded row per `(g·n, s)`
            // weight row) and the same loop nest reads them sequentially —
            // a pure data-layout change, so packed and unpacked outputs are
            // bit-identical (the pad entries are zero weights, which the
            // `w == 0` fast path skips either way).
            let sk = kernel.step();
            let ft = kernel.fwd_tables(self);
            if let Some(cp) = kernel.conv_pack(self) {
                pack_conv_weights(bv, g * n * s, pb, &ft.boffs, cp.ne, packs.b);
                let panel = &packs.b[..cp.panel_len];
                let flat = &ft.flat[..];
                match pool {
                    Some(pool) => {
                        // One task per conv output row out[g,t,n,·].
                        pool.run_chunks(out, po, |row, orow_buf| {
                            let ni = row % n;
                            let ti = (row / n) % t;
                            let gi = row / (n * t);
                            for si in 0..s {
                                let abase = ((gi * t + ti) * s + si) * pa;
                                let wrow = &panel[((gi * n + ni) * s + si) * cp.ne..][..flat.len()];
                                for (&w, &(_, aoff, ooff, len)) in wrow.iter().zip(flat) {
                                    if w == 0.0 {
                                        continue;
                                    }
                                    let a0 = abase + aoff as usize;
                                    let o0 = ooff as usize;
                                    let asl = &av[a0..a0 + len as usize];
                                    let osl = &mut orow_buf[o0..o0 + len as usize];
                                    axpy_run(table, sk, w, asl, osl);
                                }
                            }
                        });
                    }
                    None => {
                        for gi in 0..g {
                            for ti in 0..t {
                                for ni in 0..n {
                                    let ob = ((gi * t + ti) * n + ni) * po;
                                    for si in 0..s {
                                        let abase = ((gi * t + ti) * s + si) * pa;
                                        let wrow = &panel
                                            [((gi * n + ni) * s + si) * cp.ne..][..flat.len()];
                                        for (&w, &(_, aoff, ooff, len)) in wrow.iter().zip(flat) {
                                            if w == 0.0 {
                                                continue;
                                            }
                                            let a0 = abase + aoff as usize;
                                            let o0 = ob + ooff as usize;
                                            let asl = &av[a0..a0 + len as usize];
                                            let osl = &mut out[o0..o0 + len as usize];
                                            axpy_run(table, sk, w, asl, osl);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                return;
            }
            let (head, runs) = (&ft.head[..], &ft.runs[..]);
            let last = self.conv.last().unwrap();
            let (la, lb, lo) = (last.ia, last.ib, last.out);
            match pool {
                Some(pool) => {
                    // One task per conv output row out[g,t,n,·] (length po).
                    pool.run_chunks(out, po, |row, orow_buf| {
                        let ni = row % n;
                        let ti = (row / n) % t;
                        let gi = row / (n * t);
                        for si in 0..s {
                            let abase = ((gi * t + ti) * s + si) * pa;
                            let bbase = ((gi * n + ni) * s + si) * pb;
                            for &(ao, bo, poo) in head {
                                let arow = abase + ao as usize * la;
                                let brow = bbase + bo as usize * lb;
                                let obase = poo as usize * lo;
                                for &(ib, ia0, p0, len) in runs {
                                    let w = bv[brow + ib as usize];
                                    if w == 0.0 {
                                        continue;
                                    }
                                    let asl =
                                        &av[arow + ia0 as usize..arow + (ia0 + len) as usize];
                                    let osl = &mut orow_buf
                                        [obase + p0 as usize..obase + (p0 + len) as usize];
                                    axpy_run(table, sk, w, asl, osl);
                                }
                            }
                        }
                    });
                }
                None => {
                    for gi in 0..g {
                        for ti in 0..t {
                            for ni in 0..n {
                                let ob = ((gi * t + ti) * n + ni) * po;
                                for si in 0..s {
                                    let abase = ((gi * t + ti) * s + si) * pa;
                                    let bbase = ((gi * n + ni) * s + si) * pb;
                                    for &(ao, bo, poo) in head {
                                        let arow = abase + ao as usize * la;
                                        let brow = bbase + bo as usize * lb;
                                        let orow = ob + poo as usize * lo;
                                        for &(ib, ia0, p0, len) in runs {
                                            let w = bv[brow + ib as usize];
                                            if w == 0.0 {
                                                continue;
                                            }
                                            let asl = &av
                                                [arow + ia0 as usize..arow + (ia0 + len) as usize];
                                            let osl = &mut out
                                                [orow + p0 as usize..orow + (p0 + len) as usize];
                                            axpy_run(table, sk, w, asl, osl);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// Vector–Jacobian product: given `dout = ∂L/∂out`, return
    /// `(∂L/∂a, ∂L/∂b)` (default backend). This is the training-path
    /// computation whose cost the paper's tnn-cost adds as
    /// `cost(g1) + cost(g2)` (Appendix B).
    pub fn vjp(&self, a: &Tensor, b: &Tensor, dout: &Tensor) -> (Tensor, Tensor) {
        self.vjp_with(a, b, dout, &ExecOptions::default())
    }

    /// Vector–Jacobian product with an explicit backend (tables computed on
    /// the fly; repeat callers should use [`Atom::vjp_with_kernel`]).
    pub fn vjp_with(
        &self,
        a: &Tensor,
        b: &Tensor,
        dout: &Tensor,
        opts: &ExecOptions,
    ) -> (Tensor, Tensor) {
        self.vjp_with_kernel(&self.kernel(), a, b, dout, opts)
    }

    /// Vector–Jacobian product with precomputed kernel tables.
    // alloc-ok(fn): one-shot entry point; the hot path is `backward_into`
    // through a caller-held workspace.
    pub fn vjp_with_kernel(
        &self,
        kernel: &AtomKernel,
        a: &Tensor,
        b: &Tensor,
        dout: &Tensor,
        opts: &ExecOptions,
    ) -> (Tensor, Tensor) {
        let ac = canonical_input(a, &self.presum_a, &self.perm_a);
        let bc = canonical_input(b, &self.presum_b, &self.perm_b);
        // Bring dout into raw canonical order [batch, afree, bfree, conv…].
        debug_assert_eq!(dout.shape(), &self.out_shape[..]);
        let dout_c = dout.permute(&invert_perm(&self.out_perm));

        let av = ac.data();
        let bv = bc.data();
        let dv = dout_c.data();
        let mut da = vec![0.0f32; av.len()];
        let mut db = vec![0.0f32; bv.len()];
        let (pa_len, pb_len) = self.pack_lens(kernel);
        let mut pack_a_buf = vec![0.0f32; pa_len];
        let mut pack_b_buf = vec![0.0f32; pb_len];
        let mut packs = PackBufs {
            a: &mut pack_a_buf,
            b: &mut pack_b_buf,
        };
        self.backward_into(kernel, av, bv, dv, &mut da, &mut db, &mut packs, opts);

        // Undo canonicalization: permute back, then re-broadcast pre-summed
        // axes (∂/∂x of a sum over an axis broadcasts the cotangent).
        let mut da_t = Tensor::from_vec(&[da.len()], da)
            .reshape(ac.shape())
            .permute(&invert_perm(&self.perm_a));
        for &ax in self.presum_a.iter().rev() {
            // presum_a is descending; re-insert ascending.
            da_t = da_t.broadcast_axis(ax, a.shape()[ax]);
        }
        let mut db_t = Tensor::from_vec(&[db.len()], db)
            .reshape(bc.shape())
            .permute(&invert_perm(&self.perm_b));
        for &ax in self.presum_b.iter().rev() {
            db_t = db_t.broadcast_axis(ax, b.shape()[ax]);
        }
        (da_t, db_t)
    }

    /// Run the backward kernels on pre-canonicalized flat data, accumulating
    /// into `da`/`db` (which the caller must have zeroed), honouring the
    /// backend. `packs` supplies the packing scratch for the cache-blocked
    /// GEMM path (see [`Atom::pack_lens`]).
    #[allow(clippy::too_many_arguments)]
    pub fn backward_into(
        &self,
        kernel: &AtomKernel,
        av: &[f32],
        bv: &[f32],
        dv: &[f32],
        da: &mut [f32],
        db: &mut [f32],
        packs: &mut PackBufs<'_>,
        opts: &ExecOptions,
    ) {
        match opts.backend {
            Backend::Scalar => self.backward_impl(kernel, av, bv, dv, da, db, packs, None),
            Backend::Parallel { threads }
                if threads == 0 && self.flop_estimate() < self.auto_parallel_min_work(kernel) =>
            {
                self.backward_impl(kernel, av, bv, dv, da, db, packs, None)
            }
            Backend::Parallel { threads } => {
                let sized;
                let pool: &Pool = if threads == 0 {
                    Pool::global()
                } else {
                    sized = Pool::sized(threads);
                    sized.as_ref()
                };
                self.backward_impl(kernel, av, bv, dv, da, db, packs, Some(pool));
            }
        }
    }

    /// The backward kernels, serial (`pool: None`) or row-parallel. `da`
    /// and `db` route through the packed GEMM independently (each is its
    /// own matmul orientation); the unblocked fallbacks keep the v1 loop
    /// nests. Parallelism is racing-free by construction — `da` is
    /// partitioned over `(g, t)` blocks (each task owns `da[g,t,·,·]` and
    /// reduces over `n`), `db` over `(g, n)` blocks (reducing over `t`),
    /// and the packed path over microtile row bands — and every partition
    /// preserves the serial per-element accumulation order.
    #[allow(clippy::too_many_arguments)]
    fn backward_impl(
        &self,
        kernel: &AtomKernel,
        av: &[f32],
        bv: &[f32],
        dv: &[f32],
        da: &mut [f32],
        db: &mut [f32],
        packs: &mut PackBufs<'_>,
        pool: Option<&Pool>,
    ) {
        let (pa, pb, po) = self.conv_sizes();
        let (g, t, n, s) = (self.g, self.t, self.n, self.s);
        let table = kernel.table;
        if self.conv.is_empty() {
            // da[g,t,s] = Σ_n dout[g,t,n]·B[g,n,s]  — D(t×n) · B(n×s).
            if let Some(gp) = kernel.gemm.filter(|gp| gp.engages(t, s, n)) {
                for gi in 0..g {
                    let d_g = &dv[gi * t * n..(gi + 1) * t * n];
                    let b_g = &bv[gi * n * s..(gi + 1) * n * s];
                    let da_g = &mut da[gi * t * s..(gi + 1) * t * s];
                    gemm_packed(&gp, d_g, n, 1, b_g, s, 1, da_g, t, s, n, packs, pool);
                }
            } else {
                match pool {
                    Some(pool) => pool.run_chunks(da, s, |row, da_row| {
                        let ti = row % t;
                        let gi = row / t;
                        for ni in 0..n {
                            let dval = dv[(gi * t + ti) * n + ni];
                            if dval == 0.0 {
                                continue;
                            }
                            let brow = &bv[(gi * n + ni) * s..(gi * n + ni + 1) * s];
                            (table.axpy)(dval, brow, da_row);
                        }
                    }),
                    None => {
                        for gi in 0..g {
                            let d_g = &dv[gi * t * n..(gi + 1) * t * n];
                            let b_g = &bv[gi * n * s..(gi + 1) * n * s];
                            let da_g = &mut da[gi * t * s..(gi + 1) * t * s];
                            matmul_nn(table, d_g, b_g, da_g, t, s, n);
                        }
                    }
                }
            }
            // db[g,n,s] = Σ_t dout[g,t,n]·A[g,t,s]  — Dᵀ(n×t) · A(t×s).
            if let Some(gp) = kernel.gemm.filter(|gp| gp.engages(n, s, t)) {
                for gi in 0..g {
                    let d_g = &dv[gi * t * n..(gi + 1) * t * n];
                    let a_g = &av[gi * t * s..(gi + 1) * t * s];
                    let db_g = &mut db[gi * n * s..(gi + 1) * n * s];
                    gemm_packed(&gp, d_g, 1, n, a_g, s, 1, db_g, n, s, t, packs, pool);
                }
            } else {
                match pool {
                    Some(pool) => pool.run_chunks(db, s, |row, db_row| {
                        let ni = row % n;
                        let gi = row / n;
                        for ti in 0..t {
                            let dval = dv[(gi * t + ti) * n + ni];
                            if dval == 0.0 {
                                continue;
                            }
                            let arow = &av[(gi * t + ti) * s..(gi * t + ti + 1) * s];
                            (table.axpy)(dval, arow, db_row);
                        }
                    }),
                    None => {
                        for gi in 0..g {
                            let d_g = &dv[gi * t * n..(gi + 1) * t * n];
                            let a_g = &av[gi * t * s..(gi + 1) * t * s];
                            let db_g = &mut db[gi * n * s..(gi + 1) * n * s];
                            matmul_tn(table, d_g, a_g, db_g, n, s, t);
                        }
                    }
                }
            }
        } else {
            // v3 run-structured conv backward: both passes reuse the
            // forward's flattened `(head × run)` table instead of the v2
            // element-wise combined triples.
            //
            // * dA: `da[·, aoff + j] += w · dout[·, ooff + j]` — one
            //   [`axpy_run`] per live weight, with the forward's `w == 0`
            //   skip (the panel pad rides along for free).
            // * dB: `db[·, boff] += ⟨A[·, aoff..], dout[·, ooff..]⟩` — one
            //   [`dot_run`] per table entry (no skip: a zero weight still
            //   has a nonzero gradient).
            //
            // The serial nests mirror the pool partitions exactly — dA one
            // `(g, t)` block per task reducing over `n`, dB one `(g, n)`
            // block reducing over `t` — so scalar and parallel stay
            // bit-identical, and the packed panel feeds dA the same weight
            // values in the same order as the strided reads.
            let sk = kernel.step();
            let ft = kernel.fwd_tables(self);
            let flat = &ft.flat[..];
            let ne = match kernel.conv_pack(self) {
                Some(cp) => {
                    pack_conv_weights(bv, g * n * s, pb, &ft.boffs, cp.ne, packs.b);
                    cp.ne
                }
                None => 0,
            };
            let panel = &packs.b[..];
            let da_pass = |gi: usize, ti: usize, da_block: &mut [f32]| {
                for ni in 0..n {
                    let ob = ((gi * t + ti) * n + ni) * po;
                    for si in 0..s {
                        let abase = si * pa;
                        if ne > 0 {
                            let wrow = &panel[((gi * n + ni) * s + si) * ne..][..flat.len()];
                            for (&w, &(_, aoff, ooff, len)) in wrow.iter().zip(flat) {
                                if w == 0.0 {
                                    continue;
                                }
                                let o0 = ob + ooff as usize;
                                let a0 = abase + aoff as usize;
                                let dsl = &dv[o0..o0 + len as usize];
                                let asl = &mut da_block[a0..a0 + len as usize];
                                axpy_run(table, sk, w, dsl, asl);
                            }
                        } else {
                            let bbase = ((gi * n + ni) * s + si) * pb;
                            for &(boff, aoff, ooff, len) in flat {
                                let w = bv[bbase + boff as usize];
                                if w == 0.0 {
                                    continue;
                                }
                                let o0 = ob + ooff as usize;
                                let a0 = abase + aoff as usize;
                                let dsl = &dv[o0..o0 + len as usize];
                                let asl = &mut da_block[a0..a0 + len as usize];
                                axpy_run(table, sk, w, dsl, asl);
                            }
                        }
                    }
                }
            };
            let db_pass = |gi: usize, ni: usize, db_block: &mut [f32]| {
                for ti in 0..t {
                    let ob = ((gi * t + ti) * n + ni) * po;
                    for si in 0..s {
                        let abase = ((gi * t + ti) * s + si) * pa;
                        let bbase = si * pb;
                        for &(boff, aoff, ooff, len) in flat {
                            let a0 = abase + aoff as usize;
                            let o0 = ob + ooff as usize;
                            let asl = &av[a0..a0 + len as usize];
                            let dsl = &dv[o0..o0 + len as usize];
                            db_block[bbase + boff as usize] += dot_run(table, sk, asl, dsl);
                        }
                    }
                }
            };
            match pool {
                Some(pool) => {
                    pool.run_chunks(da, s * pa, |row, da_block| {
                        da_pass(row / t, row % t, da_block);
                    });
                    pool.run_chunks(db, s * pb, |row, db_block| {
                        db_pass(row / n, row % n, db_block);
                    });
                }
                None => {
                    for gi in 0..g {
                        for ti in 0..t {
                            da_pass(gi, ti, &mut da[((gi * t + ti) * s) * pa..][..s * pa]);
                        }
                    }
                    for gi in 0..g {
                        for ni in 0..n {
                            db_pass(gi, ni, &mut db[((gi * n + ni) * s) * pb..][..s * pb]);
                        }
                    }
                }
            }
        }
    }
}

// alloc-ok(fn): compile-time helper (one-shot vjp un-canonicalization).
fn invert_perm(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

/// Cache-blocked packed GEMM: `C(m×n) += A(m×k) · B(k×n)`, with the
/// operands read through generic `(row, col)` strides (a transposed
/// operand is expressed by swapping its strides, so all three matmul
/// orientations share this one driver).
///
/// Structure: the contracted index is blocked by `gp.kc`; per block the A
/// slice is packed into zero-padded `mr`-row tiles and the full `nr`-column
/// tiles of B into column-interleaved panels, then the register-blocked
/// microtile kernel sweeps row bands × column tiles, with the ragged
/// `n % nr` column edge computed by a scalar-FMA loop straight from the
/// strided B source. Each output element is one pure FMA chain over `k`
/// ascending (C is stored and reloaded exactly at block boundaries), so
/// the result is invariant under the tiling — and under the row-band
/// parallelism: with `pool`, bands of `mr` rows are dispatched over the
/// workers, the same boundaries the serial sweep uses, making parallel
/// output bit-identical to serial.
#[allow(clippy::too_many_arguments)]
fn gemm_packed(
    gp: &GemmParams,
    a: &[f32],
    a_rs: usize,
    a_cs: usize,
    b: &[f32],
    b_rs: usize,
    b_cs: usize,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    packs: &mut PackBufs<'_>,
    pool: Option<&Pool>,
) {
    let (mr, nr) = (gp.mr, gp.nr);
    let n_full = (n / nr) * nr;
    let m_tiles = (m + mr - 1) / mr;
    debug_assert!(packs.a.len() >= m_tiles * mr * gp.kc.min(k));
    debug_assert!(packs.b.len() >= n_full * gp.kc.min(k));
    let mut k0 = 0;
    while k0 < k {
        let kc = gp.kc.min(k - k0);
        pack_a(a, a_rs, a_cs, m, k0, kc, mr, packs.a);
        pack_b(b, b_rs, b_cs, n_full, k0, kc, nr, packs.b);
        let pa_panel = &packs.a[..m_tiles * mr * kc];
        let pb_panel = &packs.b[..n_full * kc];
        let band = |tile: usize, c_band: &mut [f32]| {
            let i0 = tile * mr;
            let rows = mr.min(m - i0);
            let pa_tile = &pa_panel[tile * mr * kc..(tile + 1) * mr * kc];
            for jt in 0..n_full / nr {
                let j0 = jt * nr;
                let pb_tile = &pb_panel[jt * nr * kc..(jt + 1) * nr * kc];
                (gp.panel)(pa_tile, pb_tile, &mut c_band[j0..], n, rows, kc);
            }
            // Ragged column edge: the same pure FMA chain per element,
            // reading B straight from its strided source.
            for r in 0..rows {
                for j in n_full..n {
                    let mut acc = c_band[r * n + j];
                    for kk in 0..kc {
                        acc = pa_tile[kk * mr + r].mul_add(b[(k0 + kk) * b_rs + j * b_cs], acc);
                    }
                    c_band[r * n + j] = acc;
                }
            }
        };
        match pool {
            Some(pool) if m > mr => pool.run_chunks(c, mr * n, band),
            _ => {
                for tile in 0..m_tiles {
                    let i0 = tile * mr;
                    let rows = mr.min(m - i0);
                    band(tile, &mut c[i0 * n..(i0 + rows) * n]);
                }
            }
        }
        k0 += kc;
    }
}

/// `C(t×n) += A(t×s) · B(n×s)ᵀ` — rows of both operands contiguous, each
/// entry one `table.dot` in the variant's normative order (matching the
/// parallel backend's per-row loop bit-for-bit). This is the unblocked
/// fallback; [`Atom::forward_into`] routes tiny and GEMM-sized shapes to
/// the straight scalar loop / the packed path before reaching it.
pub fn matmul_nt(
    table: &KernelTable,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    t: usize,
    n: usize,
    s: usize,
) {
    for ti in 0..t {
        let arow = &a[ti * s..(ti + 1) * s];
        let crow = &mut c[ti * n..(ti + 1) * n];
        for ni in 0..n {
            let brow = &b[ni * s..(ni + 1) * s];
            crow[ni] += (table.dot)(arow, brow);
        }
    }
}

/// `C(t×s) += A(t×n) · B(n×s)` — accumulating `table.axpy` rows.
pub fn matmul_nn(
    table: &KernelTable,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    t: usize,
    s: usize,
    n: usize,
) {
    for ti in 0..t {
        let arow = &a[ti * n..(ti + 1) * n];
        let crow = &mut c[ti * s..(ti + 1) * s];
        for ni in 0..n {
            let av = arow[ni];
            if av == 0.0 {
                continue;
            }
            let brow = &b[ni * s..(ni + 1) * s];
            (table.axpy)(av, brow, crow);
        }
    }
}

/// `C(n×s) += A(t×n)ᵀ · B(t×s)` — accumulating `table.axpy` rows.
pub fn matmul_tn(
    table: &KernelTable,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    s: usize,
    t: usize,
) {
    for ti in 0..t {
        let arow = &a[ti * n..(ti + 1) * n];
        let brow = &b[ti * s..(ti + 1) * s];
        for ni in 0..n {
            let av = arow[ni];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[ni * s..(ni + 1) * s];
            (table.axpy)(av, brow, crow);
        }
    }
}
