//! # conv_einsum
//!
//! A production-grade reproduction of *"conv_einsum: A Framework for
//! Representation and Fast Evaluation of Multilinear Operations in
//! Convolutional Tensorial Neural Networks"* (Rabbani, Su, Liu, Chan,
//! Sangston, Huang; 2024).
//!
//! The crate implements, from scratch:
//!
//! * the **conv_einsum grammar** — einsum strings extended with a
//!   pipe-delimited convolution mode list (`"bshw,tshw->bthw|hw"`) and
//!   multi-character modes (`"(t1)(s1)"`) — in [`einsum`];
//! * a **dense tensor substrate** ([`tensor`]) and a **pairwise executor**
//!   ([`exec`]) that rewrites any 2-input conv_einsum into an atomic
//!   grouped-convolution primitive (paper §3.1);
//! * a **multi-threaded execution backend** ([`parallel`]): the atom's
//!   independent per-`(group, output-row)` GEMM-shaped blocks are dispatched
//!   across a shared persistent worker pool (std-only, no dependencies),
//!   through the runtime-dispatched SIMD microkernels in [`kernels`];
//! * the **tnn-cost model** (paper Appendix B, Eq. 5–8) with training-mode
//!   costs `cost(f) + cost(g1) + cost(g2)` in [`cost`];
//! * the **optimal sequencer** (paper §3.2) — an exact netcon-equivalent
//!   subset-DP plus greedy / left-to-right / cost-capped searches — in
//!   [`planner`];
//! * **autodiff with gradient checkpointing** over pairwise evaluation paths
//!   (paper §3.3) in [`autodiff`];
//! * the **TNN layer zoo** — CP / Tucker / TT / TR / BT / HT convolutional
//!   layers and their reshaped variants, with compression-rate-driven rank
//!   selection (paper §2.3, Appendix A.3) — in [`tnn`];
//! * a **training substrate** ([`nn`]) used by the paper-reproduction
//!   benches (Tables 1–7, Figures 3–4);
//! * a **coordinator** ([`coordinator`]) serving batched layer-evaluation
//!   *and training-step* requests through one unified, pool-aware batching
//!   scheduler — fault-tolerant: supervised workers, request deadlines,
//!   admission control and graceful drain, exercised deterministically by
//!   the [`faults`] injection registry (cargo feature `fault-injection`) —
//!   and a **PJRT runtime** ([`runtime`]) that loads the AOT JAX/Pallas
//!   artifacts produced by `python/compile/aot.py`.
//!
//! ## Compile once, run many
//!
//! The hot-loop API is the compiled execution engine in [`exec::compiled`]:
//! a [`planner::Plan`] is lowered **once** into a [`CompiledPlan`] — every
//! step carrying its fully-resolved atom (pre-sum axes, canonical
//! permutations, conv triple tables, kernel tables) plus a liveness-based
//! workspace layout — and replayed against a caller-held [`Workspace`]:
//!
//! ```
//! use conv_einsum::{compile_expr, PlanOptions, Tensor, Workspace};
//! use conv_einsum::util::rng::Rng;
//! let mut rng = Rng::new(0);
//! let x = Tensor::rand(&[2, 3, 8, 8], -1.0, 1.0, &mut rng);
//! let w = Tensor::rand(&[4, 3, 3, 3], -1.0, 1.0, &mut rng);
//! let dims = vec![vec![2, 3, 8, 8], vec![4, 3, 3, 3]];
//! let plan = compile_expr("bshw,tshw->bthw|hw", &dims, &PlanOptions::default()).unwrap();
//! let mut ws = Workspace::new();
//! for _ in 0..3 {
//!     let y = plan.run(&[&x, &w], &mut ws).unwrap(); // no re-planning
//!     assert_eq!(y.shape(), &[2, 4, 8, 8]);
//! }
//! ```
//!
//! The workspace is plan-agnostic and reusable (one per thread); compiled
//! plans are shape-specialized and reject mismatched inputs with a
//! recompile error. [`exec::conv_einsum`] / [`exec::execute_path`] remain
//! as one-shot wrappers over compile+run; `nn` layers compile at first
//! forward (keyed by batch/spatial size), the autodiff tape replays the
//! compiled forward, and the coordinator shares compiled entries across
//! workers through [`exec::PlanCache`].
//!
//! ## Training path
//!
//! The training loop — the workload the paper actually benchmarks — has
//! the same steady-state guarantees. A compiled plan lazily builds one
//! [`exec::TrainLayout`] per checkpoint policy
//! ([`autodiff::CkptPolicy`]): a compile-time arena layout assigning
//! slots to every tape value, recompute-segment transient and cotangent
//! of the stored-forward + backward schedule. The autodiff executor
//! ([`autodiff::PathAutodiff`]) replays that schedule against a
//! caller-held [`TrainWorkspace`] (whose arena is shared with inference),
//! so a repeated `forward_with_tape_into` + `backward_into` training step
//! performs **zero heap allocations** on both backends after warm-up,
//! with gradients bit-identical to the per-value heap tape it replaced
//! (`bench_hotpath` asserts both and emits `BENCH_train.json`; layers own
//! a training workspace, and the coordinator serves ad-hoc training
//! requests on its workers' workspaces).
//!
//! [`autodiff::MemoryMeter`] reports each step's arena high-water mark
//! (the paper's Table 3 peak-memory quantity) — `StoreAll` > `Sqrt` in
//! peak, `Sqrt`/`None` pay segment recomputes instead, exactly the §3.3
//! trade-off.
//!
//! ## Unified request batching
//!
//! The coordinator coalesces **training requests like inference
//! requests**: one scheduler groups pending work by shape-compatibility
//! key (`(layer, shape)` for evals, `(expression, shapes, policy)` for
//! train steps — interleaved shapes batch independently), and a flushed
//! training batch replays through a single cached [`exec::TrainLayout`]
//! against one worker workspace, one fused `CompiledPlan::train_step` per
//! request in submission order
//! ([`autodiff::PathAutodiff::train_step_batch_into`] is the engine-level
//! batch entry point with the same contract). Input gradients split along the batch
//! mode and weight gradients accumulate per segment, so batched and
//! individually submitted training steps are **bit-identical**
//! (`tests/batch_train_parity.rs`) with zero steady-state heap
//! allocations on both backends. Batch sizing is **adaptive and
//! pool-aware** ([`coordinator::AdaptiveController`]): an idle service
//! flushes lone requests immediately, a saturated one (workers busy,
//! [`parallel::Pool::utilization`] high) holds partial batches up to the
//! configured bounds. `bench_hotpath` records infer/train/mixed
//! throughput vs the unbatched baseline in `BENCH_coordinator.json`.
//!
//! ## Backend selection
//!
//! Every execution entry point is parameterized by [`ExecOptions`] carrying
//! a [`Backend`]:
//!
//! * [`Backend::Parallel`]` { threads: 0 }` — the default — runs atoms on
//!   the shared **persistent** worker pool ([`parallel::Pool::global`]):
//!   long-lived workers parked on a condvar, sized from the
//!   `CONV_EINSUM_THREADS` environment variable or the machine's available
//!   parallelism ([`parallel::default_threads`]). Dispatching a parallel
//!   region costs a wake-up, not a thread spawn, and allocates nothing in
//!   the steady state — a compiled-plan replay on the parallel backend is
//!   as allocation-free as the scalar one. A positive `threads` count
//!   resolves to a persistent pool of that exact size
//!   ([`parallel::Pool::sized`], useful for benchmarking scaling).
//! * [`Backend::Scalar`] — the single-threaded kernels.
//!
//! Both backends execute their inner loops through the runtime-dispatched
//! SIMD microkernels in [`kernels`] (portable / AVX2+FMA / NEON variants
//! plus a packed cache-blocked GEMM, each with a fixed, documented
//! accumulation order — see [`kernels::dispatch`]), with the selected
//! variant pinned per compiled step when its kernel tables are built — so
//! scalar and parallel results are **bit-identical on every path for a
//! fixed variant**, contractions included. `CONV_EINSUM_KERNEL_VARIANT`
//! overrides detection (e.g. `portable` forces the fallback kernels).
//!
//! Plans record their backend ([`planner::PlanOptions::backend`] →
//! [`planner::Plan::backend`]), so [`exec::execute_path`], the coordinator's
//! workers and the autodiff tape all replay with the backend chosen at
//! planning time; `*_with` variants ([`exec::pairwise_with`],
//! [`exec::execute_path_with`]) override it per call. Concurrent users of
//! the shared pool (e.g. several coordinator workers) are arbitrated by the
//! pool itself: one fans out, the rest run serially — never oversubscribing.
//!
//! ## Autotuning
//!
//! [`Strategy::Measured`] replaces the analytic FLOPs ranking with
//! measured wall-clock: [`tune::calibrate_expr`] times the planner's
//! top-k candidate trees (plus bit-compatible orientation mirrors) on
//! the live backend and records the results in a persistent
//! [`cost::tuning`] cache (`CONV_EINSUM_TUNING_CACHE`), which also
//! carries per-geometry packed-GEMM blocking overrides
//! ([`kernels::dispatch::resolved_gemm`]). Measured plans are stamped
//! with the cache generation; recalibration invalidates them through
//! [`CompiledPlan::verify`] and [`exec::PlanCache`] keys, and unmeasured
//! contexts fall back to the analytic ranking. The coordinator can
//! calibrate its registered layers in the background
//! (`EvalService::calibrate_registered`).
//!
//! ## Correctness & static analysis
//!
//! The engine's invariants are machine-checked, not just documented
//! (`INVARIANTS.md` is the catalogue):
//!
//! * [`verify`] — a static plan verifier ([`CompiledPlan::verify`])
//!   simulates every compiled schedule (inference and all three
//!   checkpoint-policy training layouts) and proves arena-slot
//!   disjointness, def-before-use dataflow, in-bounds permutations and
//!   gather tables, overflow-free offset arithmetic, planner-cost/FLOP
//!   agreement, and accumulation-order version + kernel-variant pinning.
//!   It runs
//!   automatically after every compile in debug/test builds and on every
//!   [`exec::PlanCache`] insertion in release builds.
//! * [`verify::pool_model`] — a deterministic exhaustive-interleaving
//!   model checker for the [`parallel::Pool`] epoch/claim/notify protocol
//!   (no lost wakeups, no double-claimed or unclaimed chunks, no
//!   deadlock), run as an ordinary test.
//! * `tools/hotpath_lint.rs` — a source lint (CI job plus the
//!   `tests/static_analysis.rs` gate) that forbids allocation constructs
//!   and undocumented `unsafe` in the hot-path modules (`exec`,
//!   `kernels`, `parallel`, `tensor`) outside `// alloc-ok:` annotated
//!   sites.
//!
//! ## Cargo features
//!
//! * `pjrt` (off by default): compiles the XLA-backed [`runtime`] that
//!   executes AOT HLO artifacts through a PJRT CPU client. Requires adding
//!   the external `xla` crate (0.5.1) to Cargo.toml — it cannot be vendored
//!   into the offline build. With the feature off, the default build has
//!   zero external dependencies (the `anyhow` shim is vendored in-tree) and
//!   [`runtime::ArtifactRegistry::open`] returns a clear "disabled" error.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the paper-vs-measured record.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod autodiff;
pub mod coordinator;
pub mod cost;
pub mod einsum;
pub mod exec;
pub mod experiments;
pub mod faults;
pub mod kernels;
pub mod nn;
pub mod parallel;
pub mod planner;
pub mod runtime;
pub mod tensor;
pub mod tnn;
pub mod tune;
pub mod util;
pub mod verify;

pub use einsum::{EinsumSpec, ModeKind, SizedSpec};
pub use exec::{
    compile_expr, conv_einsum, conv_einsum_with, pairwise, Backend, CompiledPlan, ExecOptions,
    PlanCache, TrainLayout, TrainWorkspace, Workspace,
};
pub use parallel::Pool;
pub use planner::{
    candidate_plans, contract_path, ParseStrategyError, Plan, PlanOptions, Strategy,
};
pub use tensor::Tensor;
pub use tune::{calibrate_expr, CalibrationReport, CalibrationSpec};
pub use verify::{SimContext, VerifyError};
