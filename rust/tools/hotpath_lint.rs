//! Hot-path allocation and unsafe-hygiene lint.
//!
//! Scans the steady-state modules (`src/exec`, `src/kernels`,
//! `src/parallel`, `src/tensor`) and fails when it finds:
//!
//! * an **allocation construct** (`Vec::new`, `vec!`, `Box::new`,
//!   `format!`, `.collect(`, `.to_vec(`, …) outside a site annotated with
//!   `// alloc-ok:` — the engine's hot loops are allocation-free by
//!   design (compiled plans replay against caller-held workspaces), and
//!   every deliberate exception must say why;
//! * an **`unsafe` keyword** without a `SAFETY:` comment on the same line
//!   or within the few lines above it;
//! * a **`#[target_feature(...)]` function not declared `unsafe`** — on
//!   newer toolchains safe `target_feature` functions are callable from
//!   ordinary safe code with no feature check, so every SIMD variant entry
//!   point must be an `unsafe fn` reached only through its
//!   detection-gated dispatch wrapper;
//! * a **`#[target_feature(...)]` feature string outside the reviewed
//!   allowlist** (`avx2`, `fma`, `avx512f`, `neon`) — every feature a
//!   kernel enables must have a matching runtime-detection gate in
//!   `kernels/dispatch.rs`, so a new string has to be reviewed (detection
//!   + ragged-edge masking) before it may appear on a hot path.
//!
//! Annotation grammar (all inside ordinary `//` comments):
//!
//! * `// alloc-ok: <reason>` — allows the same line, or the next code
//!   line when the comment stands alone;
//! * `// alloc-ok(fn): <reason>` — allows the body of the next block
//!   (idiomatically: placed directly above a function, it allows that
//!   whole function);
//! * `// alloc-ok(file): <reason>` — allows the entire file (reserved
//!   for test-only oracles that live beside hot code).
//!
//! `tests.rs` files and `#[cfg(test)]` blocks are skipped: tests may
//! allocate freely. The scanner is line-based and deliberately simple —
//! it strips comments and string/char literals before matching, tracks
//! brace depth for block scopes, and over-reports rather than
//! under-reports on pathological formatting (an annotation fixes any
//! false positive and documents the site in the same stroke).
//!
//! Run via `cargo run --bin hotpath-lint` (CI) or through
//! `tests/static_analysis.rs`.

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Directories under the manifest root whose `.rs` files are hot-path.
const HOT_DIRS: &[&str] = &["src/exec", "src/kernels", "src/parallel", "src/tensor"];

/// Allocation constructs forbidden on hot paths. Matched against
/// comment- and literal-stripped source text.
const ALLOC_PATTERNS: &[&str] = &[
    "Vec::new",
    "Vec::with_capacity",
    "vec!",
    "Box::new",
    "String::new",
    "String::with_capacity",
    "String::from",
    "format!",
    ".to_vec(",
    ".to_string(",
    ".to_owned(",
    ".collect(",
];

/// How many comment lines above an `unsafe` may carry its SAFETY note.
const SAFETY_LOOKBACK: usize = 8;

/// Target features a hot-path kernel may enable. Each entry is paired
/// with a runtime-detection gate in `kernels/dispatch.rs` (`avx2`/`fma` →
/// Avx2Fma, `avx512f` → Avx512, `neon` → Neon); anything else is a
/// feature nobody reviewed a detection path or ragged-edge story for.
const ALLOWED_TARGET_FEATURES: &[&str] = &["avx2", "fma", "avx512f", "neon"];

#[derive(Debug)]
pub struct Finding {
    pub file: PathBuf,
    pub line: usize,
    pub what: String,
}

fn main() -> ExitCode {
    // Optional explicit root (for linting a checkout from elsewhere);
    // defaults to the crate the binary was built from.
    let root = env::args()
        .nth(1)
        .map(PathBuf::from)
        .or_else(|| env::var("CARGO_MANIFEST_DIR").ok().map(PathBuf::from))
        .unwrap_or_else(|| PathBuf::from("."));

    let mut files_scanned = 0usize;
    let mut findings = Vec::new();
    for dir in HOT_DIRS {
        let path = root.join(dir);
        if !path.is_dir() {
            eprintln!("hotpath-lint: missing hot dir {}", path.display());
            return ExitCode::FAILURE;
        }
        scan_dir(&path, &mut findings, &mut files_scanned);
    }

    if findings.is_empty() {
        println!(
            "hotpath-lint: clean ({} files across {} hot dirs)",
            files_scanned,
            HOT_DIRS.len()
        );
        ExitCode::SUCCESS
    } else {
        for f in &findings {
            eprintln!("{}:{}: {}", f.file.display(), f.line, f.what);
        }
        eprintln!(
            "hotpath-lint: {} violation(s) in {} files scanned",
            findings.len(),
            files_scanned
        );
        ExitCode::FAILURE
    }
}

fn scan_dir(dir: &Path, findings: &mut Vec<Finding>, files_scanned: &mut usize) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => {
            findings.push(Finding {
                file: dir.to_path_buf(),
                line: 0,
                what: format!("unreadable directory: {e}"),
            });
            return;
        }
    };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    paths.sort();
    for path in paths {
        if path.is_dir() {
            scan_dir(&path, findings, files_scanned);
        } else if path.extension().is_some_and(|x| x == "rs") {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name == "tests.rs" {
                continue; // test modules allocate freely
            }
            *files_scanned += 1;
            match fs::read_to_string(&path) {
                Ok(src) => scan_file(&path, &src, findings),
                Err(e) => findings.push(Finding {
                    file: path.clone(),
                    line: 0,
                    what: format!("unreadable file: {e}"),
                }),
            }
        }
    }
}

/// Per-file scan state machine.
fn scan_file(path: &Path, src: &str, findings: &mut Vec<Finding>) {
    let file_allowed = src.contains("alloc-ok(file):");

    let mut depth = 0usize;
    let mut in_block_comment = false;

    // `#[cfg(test)]` skipping: armed by the attribute, engaged at the next
    // `{`, released when depth returns to the entry level.
    let mut cfg_test_armed = false;
    let mut cfg_test_depth: Option<usize> = None;

    // `alloc-ok(fn)` scoping: armed by the annotation, engaged at the next
    // `{`, released when depth returns to the entry level.
    let mut fn_allow_armed = false;
    let mut fn_allow_depth: Option<usize> = None;

    // `alloc-ok:` on a standalone comment line allows the next code line.
    let mut line_allow_pending = false;

    // `#[target_feature(...)]` arming: the next line introducing a `fn`
    // must declare it `unsafe` (disarmed once that fn is seen).
    let mut target_feature_armed = false;

    // Rolling window of recent comment text for the SAFETY lookback.
    let mut recent_comments: Vec<String> = Vec::new();

    for (i, raw) in src.lines().enumerate() {
        let lineno = i + 1;
        let (code, comment, still_in_block) = split_code_comment(raw, in_block_comment);
        in_block_comment = still_in_block;
        let code_trim = code.trim();

        let in_test_block = cfg_test_depth.is_some();
        let in_fn_allow = fn_allow_depth.is_some();

        // -- annotations (read from the comment text) ----------------------
        let has_fn_allow_here = comment.contains("alloc-ok(fn):");
        let has_line_allow_here = comment.contains("alloc-ok:");
        if has_fn_allow_here {
            fn_allow_armed = true;
        }

        // -- cfg(test) arming ---------------------------------------------
        if code.contains("#[cfg(test)]") {
            cfg_test_armed = true;
        }

        // -- target_feature hygiene ---------------------------------------
        if code.contains("#[target_feature(") {
            target_feature_armed = true;
            // Feature strings live in literals the code half masks out, so
            // read them from the raw line.
            for feat in quoted_strings(raw) {
                if !ALLOWED_TARGET_FEATURES.contains(&feat.as_str()) {
                    findings.push(Finding {
                        file: path.to_path_buf(),
                        line: lineno,
                        what: format!(
                            "target feature `{feat}` is not in the reviewed allowlist \
                             {ALLOWED_TARGET_FEATURES:?} (add a runtime-detection gate \
                             in kernels/dispatch.rs first)"
                        ),
                    });
                }
            }
        }
        if target_feature_armed && contains_word(&code, "fn") {
            if !contains_word(&code, "unsafe") {
                findings.push(Finding {
                    file: path.to_path_buf(),
                    line: lineno,
                    what: "`#[target_feature]` function must be declared `unsafe` \
                           (call it only through a detection-gated dispatch wrapper)"
                        .to_string(),
                });
            }
            target_feature_armed = false;
        }

        // -- checks on this line (before brace accounting, so the line
        //    that *opens* an allowed/skipped block is itself governed by
        //    the surrounding scope) --------------------------------------
        let allocation_checked = !file_allowed
            && !in_test_block
            && !in_fn_allow
            && !has_line_allow_here
            && !line_allow_pending;
        if allocation_checked && !code_trim.is_empty() {
            for pat in ALLOC_PATTERNS {
                if code.contains(pat) {
                    findings.push(Finding {
                        file: path.to_path_buf(),
                        line: lineno,
                        what: format!(
                            "allocation construct `{pat}` on a hot path \
                             (annotate with `// alloc-ok: <reason>` if deliberate)"
                        ),
                    });
                }
            }
        }

        // `unsafe` hygiene applies everywhere, annotations or not (tests
        // included: an undocumented unsafe block is never fine).
        if contains_word(&code, "unsafe") {
            let documented = has_safety(&comment)
                || recent_comments
                    .iter()
                    .rev()
                    .take(SAFETY_LOOKBACK)
                    .any(|c| has_safety(c));
            if !documented {
                findings.push(Finding {
                    file: path.to_path_buf(),
                    line: lineno,
                    what: "`unsafe` without a SAFETY comment on or above it".to_string(),
                });
            }
        }

        // -- brace accounting ---------------------------------------------
        for ch in code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if cfg_test_armed && cfg_test_depth.is_none() {
                        cfg_test_armed = false;
                        cfg_test_depth = Some(depth - 1);
                    }
                    if fn_allow_armed && fn_allow_depth.is_none() {
                        fn_allow_armed = false;
                        fn_allow_depth = Some(depth - 1);
                    }
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if cfg_test_depth == Some(depth) {
                        cfg_test_depth = None;
                    }
                    if fn_allow_depth == Some(depth) {
                        fn_allow_depth = None;
                    }
                }
                _ => {}
            }
        }

        // -- bookkeeping for the next line --------------------------------
        line_allow_pending = has_line_allow_here && code_trim.is_empty();
        if code_trim.is_empty() && !comment.is_empty() {
            recent_comments.push(comment);
        } else if !comment.is_empty() {
            // a trailing comment still counts for lookback
            recent_comments.push(comment);
        } else if !code_trim.is_empty() {
            // code with no comment breaks a SAFETY/annotation run only
            // partially: keep the window rolling but record a blank so a
            // SAFETY note can't act at a distance across real code.
            recent_comments.push(String::new());
        }
        if recent_comments.len() > SAFETY_LOOKBACK * 2 {
            recent_comments.drain(..recent_comments.len() - SAFETY_LOOKBACK * 2);
        }
    }
}

/// All `"..."` literal contents on a raw source line (no escape handling:
/// target-feature strings are plain identifiers).
fn quoted_strings(raw: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = raw;
    while let Some(open) = rest.find('"') {
        let after = &rest[open + 1..];
        match after.find('"') {
            Some(close) => {
                out.push(after[..close].to_string());
                rest = &after[close + 1..];
            }
            None => break,
        }
    }
    out
}

fn has_safety(comment: &str) -> bool {
    let c = comment.to_ascii_lowercase();
    c.contains("safety")
}

/// Whole-word containment (so `AssertUnwindSafe` or an identifier like
/// `unsafety` never trips the check).
fn contains_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident_char(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident_char(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Split a source line into (code-with-literals-masked, comment-text).
/// Handles `//` comments, `/* */` block comments (possibly spanning
/// lines), string literals with escapes, and char literals — all masked
/// out of the code half so patterns never match inside them. Returns the
/// block-comment state for the next line.
fn split_code_comment(raw: &str, mut in_block: bool) -> (String, String, bool) {
    let mut code = String::with_capacity(raw.len());
    let mut comment = String::new();
    let chars: Vec<char> = raw.chars().collect();
    let mut i = 0usize;
    while i < chars.len() {
        if in_block {
            if chars[i] == '*' && i + 1 < chars.len() && chars[i + 1] == '/' {
                in_block = false;
                i += 2;
            } else {
                comment.push(chars[i]);
                i += 1;
            }
            continue;
        }
        let c = chars[i];
        match c {
            '/' if i + 1 < chars.len() && chars[i + 1] == '/' => {
                // line comment: the rest is comment text
                comment.push_str(&raw[raw.char_indices().nth(i).map(|(b, _)| b).unwrap_or(0)..]);
                return (code, comment, false);
            }
            '/' if i + 1 < chars.len() && chars[i + 1] == '*' => {
                in_block = true;
                i += 2;
            }
            '"' => {
                // string literal: skip to the unescaped closing quote
                i += 1;
                while i < chars.len() {
                    match chars[i] {
                        '\\' => i += 2,
                        '"' => {
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
                code.push('"');
                code.push('"');
            }
            '\'' => {
                // char literal vs lifetime: a char literal closes within a
                // few chars (`'x'`, `'\n'`, `'\u{1F600}'` is rare enough to
                // over-approximate); a lifetime never has a closing quote
                // before a non-ident char.
                let mut j = i + 1;
                if j < chars.len() && chars[j] == '\\' {
                    j += 2;
                    while j < chars.len() && chars[j] != '\'' {
                        j += 1;
                    }
                    i = (j + 1).min(chars.len());
                    code.push('\'');
                    code.push('\'');
                } else if j + 1 < chars.len() && chars[j + 1] == '\'' {
                    i = j + 2; // simple 'x'
                    code.push('\'');
                    code.push('\'');
                } else {
                    code.push(c); // lifetime tick
                    i += 1;
                }
            }
            _ => {
                code.push(c);
                i += 1;
            }
        }
    }
    (code, comment, in_block)
}
