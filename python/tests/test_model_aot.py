"""L2 model + AOT pipeline tests: planned-path execution matches the
oracle; the train step learns; lowering produces loadable HLO text."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.conv_einsum import contract_path
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape).astype(np.float32))


def full_ref(expr, tensors):
    """Evaluate an N-input expression left-to-right with the oracle."""
    from compile.conv_einsum import Ctx, Sized, parse

    spec = parse(expr)
    sized = Sized(spec, [list(t.shape) for t in tensors])
    ctx = Ctx(sized)
    vals = {1 << i: np.asarray(t, np.float64) for i, t in enumerate(tensors)}
    acc = 1
    for i in range(1, len(tensors)):
        a = ctx.subset(acc)
        b = ctx.leaf(i)
        merged = ctx.subset(acc | (1 << i))
        conv = [m for m in spec.conv if m in a.modes and m in b.modes]
        vals[acc | (1 << i)] = ref.pairwise_ref(
            a.modes, b.modes, merged.modes, conv, vals.pop(acc), vals.pop(1 << i)
        )
        acc |= 1 << i
    root = ctx.subset(acc)
    perm = [root.modes.index(m) for m in spec.output]
    return np.transpose(vals[acc], perm)


CP_EXPR = "bshw,rt,rs,rh,rw->bthw|hw"
CP_DIMS = [[2, 3, 8, 8], [4, 5], [4, 3], [4, 3], [4, 3]]


class TestPathForward:
    def test_cp_layer_optimal_matches_oracle(self):
        tensors = [rand(s, i) for i, s in enumerate(CP_DIMS)]
        fn = model.tnn_layer_forward(CP_EXPR, CP_DIMS, strategy="optimal")
        got = np.asarray(fn(*tensors))
        want = full_ref(CP_EXPR, tensors)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_cp_layer_ltr_matches_oracle(self):
        tensors = [rand(s, 10 + i) for i, s in enumerate(CP_DIMS)]
        fn = model.tnn_layer_forward(CP_EXPR, CP_DIMS, strategy="ltr")
        got = np.asarray(fn(*tensors))
        want = full_ref(CP_EXPR, tensors)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_optimal_path_is_cheaper(self):
        p = contract_path(CP_EXPR, CP_DIMS)
        assert p["cost"] < p["naive_cost"]

    def test_rcp_layer(self):
        expr = "b(s1)(s2)hw,r(t1)(s1),r(t2)(s2),rhw->b(t1)(t2)hw|hw"
        dims = [[1, 2, 3, 6, 6], [4, 2, 2], [4, 3, 3], [4, 3, 3]]
        tensors = [rand(s, 20 + i) for i, s in enumerate(dims)]
        fn = model.tnn_layer_forward(expr, dims, strategy="optimal")
        got = np.asarray(fn(*tensors))
        want = full_ref(expr, tensors)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
        assert got.shape == (1, 2, 3, 6, 6)

    def test_jnp_atoms_match_pallas_atoms(self):
        tensors = [rand(s, 30 + i) for i, s in enumerate(CP_DIMS)]
        pallas_fn = model.tnn_layer_forward(CP_EXPR, CP_DIMS, use_pallas=True)
        jnp_fn = model.tnn_layer_forward(CP_EXPR, CP_DIMS, use_pallas=False)
        np.testing.assert_allclose(
            np.asarray(pallas_fn(*tensors)),
            np.asarray(jnp_fn(*tensors)),
            rtol=1e-3,
            atol=1e-4,
        )


class TestTrainStep:
    def test_loss_decreases(self):
        expr = "bshw,rt,rs,rh,rw->bthw|hw"
        dims = [[8, 2, 8, 8], [3, 4], [3, 2], [3, 3], [3, 3]]
        n_classes = 3
        step = jax.jit(model.tiny_tnn_train_step(expr, dims, n_classes, lr=0.1))
        rng = np.random.default_rng(0)
        x = rand(dims[0], 40)
        labels = rng.integers(0, n_classes, size=8)
        onehot = jnp.asarray(np.eye(n_classes, dtype=np.float32)[labels])
        params = [rand(s, 41 + i) for i, s in enumerate(dims[1:])]
        params += [rand([4, n_classes], 50), jnp.zeros((n_classes,))]
        losses = []
        for _ in range(12):
            loss, *params = step(x, onehot, *params)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses


class TestAot:
    def test_lowering_produces_hlo_text(self, tmp_path):
        fn = model.tnn_layer_forward(CP_EXPR, CP_DIMS)
        lowered = aot.lower_fn(lambda *a: (fn(*a),), CP_DIMS)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert len(text) > 500

    @pytest.mark.slow
    def test_full_aot_build(self, tmp_path, monkeypatch):
        import sys

        monkeypatch.setattr(sys, "argv", ["aot", "--out", str(tmp_path)])
        aot.main()
        manifest = os.path.join(tmp_path, "manifest.json")
        assert os.path.exists(manifest)
        import json

        data = json.load(open(manifest))
        assert len(data["artifacts"]) >= 4
        for a in data["artifacts"]:
            assert os.path.exists(os.path.join(tmp_path, a["file"]))
