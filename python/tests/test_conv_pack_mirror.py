"""Differential mirror of the Rust conv-atom packed-panel math.

This file transcribes, in numpy, the index algebra behind the packed
run-structured conv-atom panels in ``rust/src/exec/atom.rs`` and
``rust/src/kernels/pack.rs``:

* ``conv_triples`` — the per-axis ``(a, b, p)`` triple enumeration for
  Same / Valid / Full / Circular convolution kinds,
* ``head_and_runs`` — cross-product head over all-but-last conv axes,
  unit-stride run merging on the last axis,
* ``fwd_tables`` — the flattened head-major × runs table
  ``(boff, aoff, ooff, len)`` and its ``boffs`` gather column,
* ``pack_conv_weights`` — the zero-padded consumption-ordered weight
  panel (``ne`` = run count rounded up to the 8-lane width),
* the packed and unpacked forward loops, and the v3 run-structured
  backward passes (dA with the ``w == 0`` skip, dB without it).

Each piece is checked against an independent brute-force oracle built
straight from the combined triples, over all four conv kinds, flipped
feature/filter orders, a two-axis geometry with a non-trivial head, and
a modulus-clamped circular case.  Inputs are small integers stored as
float32, so every sum is exact and the comparisons are bit-strict —
the same quantifier the Rust suite uses for packed-vs-unpacked parity.
"""

import numpy as np
import pytest

LANES = 8  # kernels/pack.rs pads each panel row to this multiple.


class ConvAxis:
    def __init__(self, kind, ia, ib, modulus=None):
        assert kind in ("same", "valid", "full", "circular")
        self.kind = kind
        self.ia = ia
        self.ib = ib
        self.modulus = modulus

    def out_len(self):
        feat = max(self.ia, self.ib)
        filt = min(self.ia, self.ib)
        if self.kind == "full":
            return self.ia + self.ib - 1
        if self.kind == "same":
            return feat
        if self.kind == "valid":
            return feat - filt + 1
        m = feat if self.modulus is None else self.modulus
        return min(self.ia + self.ib - 1, m)


def conv_triples(c):
    """Mirror of atom.rs::conv_triples — a-major, then b."""
    feat = max(c.ia, c.ib)
    filt = min(c.ia, c.ib)
    triples = []
    for a in range(c.ia):
        for b in range(c.ib):
            if c.kind == "full":
                triples.append((a, b, a + b))
            elif c.kind == "circular":
                m = feat if c.modulus is None else c.modulus
                triples.append((a, b, (a + b) % m))
            elif c.kind == "same":
                p = a + b - (filt - 1) // 2
                if 0 <= p < feat:
                    triples.append((a, b, p))
            else:  # valid
                p = a + b - (filt - 1)
                if 0 <= p < feat - filt + 1:
                    triples.append((a, b, p))
    return triples


def combined_triples(axes):
    """Cross-product of the per-axis triples with row-major flattening."""
    combo = [(0, 0, 0)]
    for c in axes:
        combo = [
            (ao * c.ia + ia, bo * c.ib + ib, po * c.out_len() + p)
            for (ao, bo, po) in combo
            for (ia, ib, p) in conv_triples(c)
        ]
    return combo


def head_and_runs(axes):
    """Mirror of atom.rs::head_and_runs."""
    head = [(0, 0, 0)]
    for c in axes[:-1]:
        head = [
            (ao * c.ia + ia, bo * c.ib + ib, po * c.out_len() + p)
            for (ao, bo, po) in head
            for (ia, ib, p) in conv_triples(c)
        ]
    last = axes[-1]
    by_ib = [[] for _ in range(last.ib)]
    for (ia, ib, p) in conv_triples(last):
        by_ib[ib].append((ia, p))
    runs = []
    for ib, pairs in enumerate(by_ib):
        pairs.sort()
        for (ia, p) in pairs:
            if runs and runs[-1][0] == ib:
                _, ia0, p0, ln = runs[-1]
                if ia == ia0 + ln and p == p0 + ln:
                    runs[-1] = (ib, ia0, p0, ln + 1)
                    continue
            runs.append((ib, ia, p, 1))
    return head, runs


def fwd_tables(axes):
    """Mirror of AtomKernel::fwd_tables — flat table plus gather column."""
    head, runs = head_and_runs(axes)
    last = axes[-1]
    la, lb, lo = last.ia, last.ib, last.out_len()
    flat = [
        (bo * lb + ib, ao * la + ia0, po * lo + p0, ln)
        for (ao, bo, po) in head
        for (ib, ia0, p0, ln) in runs
    ]
    boffs = [entry[0] for entry in flat]
    return flat, boffs


def round_up_lanes(entries):
    return (entries + LANES - 1) // LANES * LANES


def pack_conv_weights(bv, rows, pb, boffs, ne):
    """Mirror of kernels/pack.rs::pack_conv_weights (zero-padded gather)."""
    panel = np.zeros(rows * ne, dtype=np.float32)
    for r in range(rows):
        for e, boff in enumerate(boffs):
            panel[r * ne + e] = bv[r * pb + boff]
    return panel


class Atom:
    def __init__(self, g, t, n, s, axes):
        self.g, self.t, self.n, self.s, self.axes = g, t, n, s, axes
        self.pa = int(np.prod([c.ia for c in axes]))
        self.pb = int(np.prod([c.ib for c in axes]))
        self.po = int(np.prod([c.out_len() for c in axes]))


def forward_mirror(atom, av, bv, packed):
    """The forward_impl conv nest: packed panel or strided weight reads."""
    g, t, n, s = atom.g, atom.t, atom.n, atom.s
    pa, pb, po = atom.pa, atom.pb, atom.po
    flat, boffs = fwd_tables(atom.axes)
    ne = round_up_lanes(len(flat))
    panel = pack_conv_weights(bv, g * n * s, pb, boffs, ne) if packed else None
    out = np.zeros(g * t * n * po, dtype=np.float32)
    for gi in range(g):
        for ti in range(t):
            for ni in range(n):
                ob = ((gi * t + ti) * n + ni) * po
                for si in range(s):
                    abase = ((gi * t + ti) * s + si) * pa
                    row = ((gi * n + ni) * s + si) * ne
                    bbase = ((gi * n + ni) * s + si) * pb
                    for e, (boff, aoff, ooff, ln) in enumerate(flat):
                        w = panel[row + e] if packed else bv[bbase + boff]
                        if w == 0.0:
                            continue
                        dst = slice(ob + ooff, ob + ooff + ln)
                        src = slice(abase + aoff, abase + aoff + ln)
                        out[dst] += w * av[src]
    return out


def backward_mirror(atom, av, bv, dv, packed):
    """The v3 run-structured backward: dA (with w==0 skip) and dB."""
    g, t, n, s = atom.g, atom.t, atom.n, atom.s
    pa, pb, po = atom.pa, atom.pb, atom.po
    flat, boffs = fwd_tables(atom.axes)
    ne = round_up_lanes(len(flat))
    panel = pack_conv_weights(bv, g * n * s, pb, boffs, ne) if packed else None
    da = np.zeros(g * t * s * pa, dtype=np.float32)
    db = np.zeros(g * n * s * pb, dtype=np.float32)
    for gi in range(g):
        for ti in range(t):
            for ni in range(n):
                ob = ((gi * t + ti) * n + ni) * po
                for si in range(s):
                    abase = ((gi * t + ti) * s + si) * pa
                    row = ((gi * n + ni) * s + si) * ne
                    bbase = ((gi * n + ni) * s + si) * pb
                    for e, (boff, aoff, ooff, ln) in enumerate(flat):
                        asl = slice(abase + aoff, abase + aoff + ln)
                        osl = slice(ob + ooff, ob + ooff + ln)
                        w = panel[row + e] if packed else bv[bbase + boff]
                        if w != 0.0:
                            da[asl] += w * dv[osl]
                        db[bbase + boff] += float(np.dot(av[asl], dv[osl]))
    return da, db


def oracle(atom, av, bv, dv):
    """Brute-force forward + grads straight from the combined triples."""
    g, t, n, s = atom.g, atom.t, atom.n, atom.s
    a4 = av.reshape(g, t, s, atom.pa)
    b4 = bv.reshape(g, n, s, atom.pb)
    d4 = dv.reshape(g, t, n, atom.po)
    out = np.zeros((g, t, n, atom.po), dtype=np.float32)
    da = np.zeros_like(a4)
    db = np.zeros_like(b4)
    for (a, b, p) in combined_triples(atom.axes):
        out[:, :, :, p] += np.einsum("gts,gns->gtn", a4[:, :, :, a], b4[:, :, :, b])
        da[:, :, :, a] += np.einsum("gtn,gns->gts", d4[:, :, :, p], b4[:, :, :, b])
        db[:, :, :, b] += np.einsum("gtn,gts->gns", d4[:, :, :, p], a4[:, :, :, a])
    return out.ravel(), da.ravel(), db.ravel()


def rand_ints(n, seed):
    """Small integers as float32: every sum below is exact, so comparisons
    are bit-strict and independent of accumulation order."""
    rng = np.random.default_rng(seed)
    return rng.integers(-3, 4, size=n).astype(np.float32)


GEOMETRIES = [
    pytest.param([ConvAxis(k, 9, 3)], id=f"{k}-1axis") for k in
    ("same", "valid", "full", "circular")
] + [
    pytest.param([ConvAxis(k, 3, 9)], id=f"{k}-flipped") for k in
    ("same", "valid", "full", "circular")
] + [
    pytest.param([ConvAxis(k, 6, 3), ConvAxis(k, 5, 2)], id=f"{k}-2axis")
    for k in ("same", "valid", "full", "circular")
] + [
    pytest.param([ConvAxis("circular", 7, 3, modulus=5)], id="circular-modulus"),
]


def make_atom(axes):
    return Atom(g=2, t=3, n=2, s=2, axes=axes)


@pytest.mark.parametrize("axes", GEOMETRIES)
class TestConvPackMirror:
    def test_flat_table_covers_combined_triples(self, axes):
        """Expanding every run element-wise recovers exactly the combined
        triples — no entry dropped, none duplicated, none invented."""
        flat, _ = fwd_tables(axes)
        expanded = sorted(
            (boff, aoff + j, ooff + j)
            for (boff, aoff, ooff, ln) in flat
            for j in range(ln)
        )
        expected = sorted((b, a, p) for (a, b, p) in combined_triples(axes))
        assert expanded == expected

    def test_panel_width_rounds_to_lanes(self, axes):
        flat, _ = fwd_tables(axes)
        ne = round_up_lanes(len(flat))
        assert ne % LANES == 0
        assert len(flat) <= ne < len(flat) + LANES

    def test_pack_gathers_in_consumption_order_and_zero_pads(self, axes):
        atom = make_atom(axes)
        flat, boffs = fwd_tables(axes)
        ne = round_up_lanes(len(flat))
        rows = atom.g * atom.n * atom.s
        bv = rand_ints(rows * atom.pb, seed=11)
        panel = pack_conv_weights(bv, rows, atom.pb, boffs, ne)
        for r in range(rows):
            wrow = panel[r * ne:(r + 1) * ne]
            for e, boff in enumerate(boffs):
                assert wrow[e] == bv[r * atom.pb + boff]
            assert not wrow[len(flat):].any()

    def test_packed_forward_matches_unpacked_and_oracle(self, axes):
        atom = make_atom(axes)
        av = rand_ints(atom.g * atom.t * atom.s * atom.pa, seed=21)
        bv = rand_ints(atom.g * atom.n * atom.s * atom.pb, seed=22)
        dv = rand_ints(atom.g * atom.t * atom.n * atom.po, seed=23)
        want, _, _ = oracle(atom, av, bv, dv)
        packed = forward_mirror(atom, av, bv, packed=True)
        unpacked = forward_mirror(atom, av, bv, packed=False)
        assert np.array_equal(packed, unpacked)
        assert np.array_equal(packed, want)

    def test_run_structured_backward_matches_oracle(self, axes):
        atom = make_atom(axes)
        av = rand_ints(atom.g * atom.t * atom.s * atom.pa, seed=31)
        bv = rand_ints(atom.g * atom.n * atom.s * atom.pb, seed=32)
        dv = rand_ints(atom.g * atom.t * atom.n * atom.po, seed=33)
        _, want_da, want_db = oracle(atom, av, bv, dv)
        for packed in (True, False):
            da, db = backward_mirror(atom, av, bv, dv, packed=packed)
            assert np.array_equal(da, want_da)
            assert np.array_equal(db, want_db)

    def test_zero_weights_do_not_change_grad_b(self, axes):
        """The dA pass may skip w == 0 (a zero weight contributes nothing),
        but dB must NOT skip: a zero weight still has a nonzero gradient."""
        atom = make_atom(axes)
        av = rand_ints(atom.g * atom.t * atom.s * atom.pa, seed=41)
        bv = rand_ints(atom.g * atom.n * atom.s * atom.pb, seed=42)
        dv = rand_ints(atom.g * atom.t * atom.n * atom.po, seed=43)
        bv[::3] = 0.0  # force plenty of skipped weights
        _, want_da, want_db = oracle(atom, av, bv, dv)
        da, db = backward_mirror(atom, av, bv, dv, packed=True)
        assert np.array_equal(da, want_da)
        assert np.array_equal(db, want_db)
