"""Python planner mirror: unit tests + cross-language golden comparison
against the rust planner (runs the rust CLI when the binary exists)."""

import json
import os
import subprocess

import pytest
from hypothesis import given, settings, strategies as st

from compile.conv_einsum import contract_path, parse

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))

FIXTURES = [
    ("ij,jk,kl->il", [[2, 3], [3, 100], [100, 2]]),
    ("ijk,jl,lmq,njpq->ijknp|j", [[4, 7, 9], [10, 5], [5, 4, 2], [6, 8, 9, 2]]),
    ("bshw,rt,rs,rh,rw->bthw|hw", [[2, 3, 16, 16], [4, 8], [4, 3], [4, 3], [4, 3]]),
    (
        "b(s1)(s2)hw,r(t1)(s1),r(t2)(s2),rhw->b(t1)(t2)hw|hw",
        [[2, 3, 4, 12, 12], [5, 3, 3], [5, 2, 4], [5, 3, 3]],
    ),
    ("bfsh,fgh,sth->bgth|h", [[2, 3, 4, 16], [3, 5, 3], [4, 6, 3]]),
]


def test_parse_roundtrip():
    s = parse("b(s1)(s2)hw,r(t1)(s1)->b(t1)hw|hw")
    assert s.render() == "b(s1)(s2)hw,r(t1)(s1)->b(t1)hw|hw"
    assert s.conv == ["h", "w"]


def test_parse_rejects_bad():
    with pytest.raises(ValueError):
        parse("ab,bc")
    with pytest.raises(ValueError):
        parse("ab,bc->az")
    with pytest.raises(ValueError):
        parse("ah,bh->ab|h")  # conv mode not in output


def test_matmul_chain_cost():
    p = contract_path("ij,jk,kl->il", [[2, 3], [3, 100], [100, 2]])
    assert p["cost"] == 612.0  # A(BC)
    assert p["naive_cost"] == 1000.0  # (AB)C


def test_optimal_never_worse_than_naive():
    for expr, dims in FIXTURES:
        p = contract_path(expr, dims)
        assert p["cost"] <= p["naive_cost"] + 1e-9, expr


def test_training_cost_exceeds_forward():
    expr, dims = FIXTURES[2]
    fwd = contract_path(expr, dims, training=False)
    trn = contract_path(expr, dims, training=True)
    assert trn["cost"] >= 2.0 * fwd["cost"]


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.integers(2, 4),
    r_extra=st.integers(0, 3),
    h=st.integers(2, 3),
    mult=st.integers(6, 10),
)
def test_theorem1_cheaper_path_exists(b, s, r_extra, h, mult):
    """Theorem 1: RCP layers with H'>>H and R >= S have a cheaper-than-naive
    path; the sequencer must find one."""
    r = s * s + r_extra
    hp = h * mult
    expr = "b(s1)(s2)hw,r(t1)(s1),r(t2)(s2),rhw->b(t1)(t2)hw|hw"
    dims = [[b, s, s, hp, hp], [r, s, s], [r, s, s], [r, h, h]]
    p = contract_path(expr, dims)
    assert p["cost"] < p["naive_cost"]


def _rust_binary():
    for profile in ("release", "debug"):
        p = os.path.join(REPO, "target", profile, "conv-einsum")
        if os.path.exists(p):
            return p
    return None


@pytest.mark.skipif(_rust_binary() is None, reason="rust binary not built")
def test_golden_against_rust_planner():
    """The rust planner and this mirror must agree on total/naive costs and
    largest intermediate for every fixture (paths may tie-break differently;
    costs may not)."""
    binary = _rust_binary()
    for expr, dims in FIXTURES:
        dims_arg = ";".join(",".join(str(d) for d in dd) for dd in dims)
        out = subprocess.run(
            [binary, "plan", expr, "--dims", dims_arg, "--json"],
            capture_output=True,
            text=True,
            check=True,
        )
        rust = json.loads(out.stdout)
        py = contract_path(expr, dims)
        assert rust["cost"] == pytest.approx(py["cost"], rel=1e-9), expr
        assert rust["naive_cost"] == pytest.approx(py["naive_cost"], rel=1e-9), expr
        assert rust["largest_intermediate"] == pytest.approx(
            py["largest_intermediate"], rel=1e-9
        ), expr
