"""L1 kernel correctness: Pallas atoms vs the numpy oracle, swept over
shapes/dtypes with hypothesis. The CORE correctness signal for the kernels
that end up inside the AOT artifacts."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_atom, ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)


class TestMatmulAtom:
    def test_basic(self):
        a = rand((2, 3, 4), 0)
        b = rand((2, 5, 4), 1)
        got = np.asarray(conv_atom.matmul_atom(a, b))
        want = ref.matmul_atom_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        g=st.integers(1, 3),
        t=st.integers(1, 6),
        n=st.integers(1, 6),
        s=st.integers(1, 8),
        seed=st.integers(0, 2**31),
    )
    def test_property(self, g, t, n, s, seed):
        a = rand((g, t, s), seed)
        b = rand((g, n, s), seed + 1)
        got = np.asarray(conv_atom.matmul_atom(a, b))
        want = ref.matmul_atom_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_dtype_promotion(self):
        a = rand((1, 2, 3), 2).astype(np.float64)
        b = rand((1, 2, 3), 3).astype(np.float64)
        got = np.asarray(conv_atom.matmul_atom(a, b))
        assert got.dtype == np.float32  # kernel computes in f32


class TestConv2dAtom:
    def test_identity_filter(self):
        # 1x1 filter of ones with S=1,N=1 = per-channel copy scaled
        a = rand((1, 2, 1, 5, 5), 4)
        b = np.ones((1, 1, 1, 1, 1), np.float32)
        got = np.asarray(conv_atom.conv2d_atom(a, b))
        np.testing.assert_allclose(got[:, :, 0], a[:, :, 0], rtol=1e-5)

    def test_against_oracle(self):
        a = rand((2, 3, 2, 6, 5), 5)
        b = rand((2, 2, 2, 3, 3), 6)
        got = np.asarray(conv_atom.conv2d_atom(a, b))
        want = ref.conv2d_atom_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        g=st.integers(1, 2),
        t=st.integers(1, 4),
        n=st.integers(1, 3),
        s=st.integers(1, 3),
        ha=st.integers(3, 8),
        hb=st.sampled_from([1, 3]),
        seed=st.integers(0, 2**31),
    )
    def test_property(self, g, t, n, s, ha, hb, seed):
        wa, wb = ha, hb
        a = rand((g, t, s, ha, wa), seed)
        b = rand((g, n, s, hb, wb), seed + 7)
        got = np.asarray(conv_atom.conv2d_atom(a, b))
        want = ref.conv2d_atom_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_even_filter(self):
        a = rand((1, 1, 1, 6, 6), 8)
        b = rand((1, 1, 1, 2, 2), 9)
        got = np.asarray(conv_atom.conv2d_atom(a, b))
        want = ref.conv2d_atom_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_t_tiling_under_small_budget(self, monkeypatch):
        # Force tiny VMEM budget → T-tiling with padding; result unchanged.
        monkeypatch.setattr(conv_atom, "VMEM_BUDGET", 6000)
        a = rand((1, 5, 2, 6, 6), 10)
        b = rand((1, 2, 2, 3, 3), 11)
        got = np.asarray(conv_atom.conv2d_atom(a, b))
        want = ref.conv2d_atom_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_vmem_model(self):
        fp = conv_atom.vmem_footprint(4, 8, 20, 20, 2, 3, 3)
        assert fp == (4 * 8 * 20 * 20 + 2 * 8 * 3 * 3 + 4 * 2 * 16 * 16) * 4
        assert 0 < conv_atom.mxu_utilization_estimate(64, 32, 16) <= 1.0


class TestPairwiseOracle:
    """Sanity of the oracle itself on hand-computable cases."""

    def test_matmul(self):
        a = np.array([[1.0, 2.0], [3.0, 4.0]])
        b = np.array([[1.0, 0.0], [0.0, 1.0]])
        got = ref.pairwise_ref(["i", "j"], ["j", "k"], ["i", "k"], [], a, b)
        np.testing.assert_allclose(got, a)

    def test_full_conv(self):
        a = np.array([1.0, 2.0, 3.0])
        b = np.array([1.0, 1.0])
        got = ref.pairwise_ref(["x"], ["x"], ["x"], ["x"], a, b, {"x": "full"})
        np.testing.assert_allclose(got, [1.0, 3.0, 5.0, 3.0])

    def test_circular_conv(self):
        a = np.array([1.0, 2.0, 3.0, 4.0])
        b = np.array([1.0, 1.0])
        got = ref.pairwise_ref(["x"], ["x"], ["x"], ["x"], a, b, {"x": "circular"})
        np.testing.assert_allclose(got, [5.0, 3.0, 5.0, 7.0])

    def test_same_conv_matches_conv2d_atom(self):
        a = rand((1, 1, 1, 5, 5), 12).astype(np.float64)
        b = rand((1, 1, 1, 3, 3), 13).astype(np.float64)
        got = ref.conv2d_atom_ref(a, b)[0, 0, 0]
        want = ref.pairwise_ref(
            ["h", "w"], ["h", "w"], ["h", "w"], ["h", "w"],
            a[0, 0, 0], b[0, 0, 0], {"h": "same", "w": "same"},
        )
        np.testing.assert_allclose(got, want, rtol=1e-6)
