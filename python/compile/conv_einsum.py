"""Python mirror of the rust conv_einsum planner (build-time only).

The optimal sequencer must run at AOT time to bake the evaluation path into
the lowered JAX graph. This module mirrors `rust/src/{einsum,cost,planner}`:
same grammar, same tnn-cost model (paper Appendix B Eq. 5-8), same exact
subset-DP optimum. Cross-language equivalence is enforced by golden tests
(python/tests/test_planner.py runs the rust CLI when the binary is built).

Not a runtime component: python never executes on the request path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


# ---------------------------------------------------------------------------
# Grammar
# ---------------------------------------------------------------------------

def parse_subscripts(text: str) -> list[str]:
    """Parse one subscript group: single letters or parenthesized names."""
    modes = []
    i = 0
    text = text.strip()
    while i < len(text):
        c = text[i]
        if c.isspace():
            i += 1
        elif c == "(":
            close = text.index(")", i + 1)
            name = text[i + 1 : close].strip()
            if not name:
                raise ValueError("empty mode name '()'")
            modes.append(name)
            i = close + 1
        elif c.isalpha():
            modes.append(c)
            i += 1
        else:
            raise ValueError(f"unexpected character {c!r} in subscripts")
    return modes


@dataclass
class Spec:
    """Parsed conv_einsum expression."""

    inputs: list[list[str]]
    output: list[str]
    conv: list[str]

    def occurrences(self, m: str) -> int:
        return sum(1 for modes in self.inputs if m in modes)

    def all_modes(self) -> list[str]:
        seen, out = set(), []
        for modes in self.inputs + [self.output]:
            for m in modes:
                if m not in seen:
                    seen.add(m)
                    out.append(m)
        return out

    def render(self) -> str:
        def sub(modes):
            return "".join(m if len(m) == 1 else f"({m})" for m in modes)

        s = ",".join(sub(i) for i in self.inputs) + "->" + sub(self.output)
        if self.conv:
            s += "|" + sub(self.conv)
        return s


def parse(expr: str) -> Spec:
    """Parse a conv_einsum string like 'bshw,rt,rs,rh,rw->bthw|hw'."""
    if "->" not in expr:
        raise ValueError("missing '->'")
    lhs, rhs = expr.split("->", 1)
    if "|" in rhs:
        out_part, conv_part = rhs.split("|", 1)
        conv = [m for seg in conv_part.split(",") for m in parse_subscripts(seg)]
        if not conv:
            raise ValueError("empty convolution list")
        if len(set(conv)) != len(conv):
            raise ValueError("duplicate convolution mode")
    else:
        out_part, conv = rhs, []
    inputs = [parse_subscripts(seg) for seg in lhs.split(",")]
    output = parse_subscripts(out_part)
    spec = Spec(inputs, output, conv)
    # validation (mirrors rust EinsumSpec::validate)
    for i, modes in enumerate(spec.inputs):
        if len(set(modes)) != len(modes):
            raise ValueError(f"input {i} repeats a mode")
    if len(set(spec.output)) != len(spec.output):
        raise ValueError("output repeats a mode")
    for m in spec.output:
        if spec.occurrences(m) == 0:
            raise ValueError(f"output mode {m!r} not in any input")
    for m in spec.conv:
        if m not in spec.output:
            raise ValueError(f"conv mode {m!r} must appear in the output")
        if spec.occurrences(m) == 0:
            raise ValueError(f"conv mode {m!r} not in any input")
    return spec


# ---------------------------------------------------------------------------
# Sized spec + cost model (Appendix B)
# ---------------------------------------------------------------------------

def conv_out_size(kind: str, ia: int, ib: int, modulus: int | None) -> int:
    feat, filt = max(ia, ib), min(ia, ib)
    if kind == "circular":
        p = modulus if modulus is not None else feat
        return min(ia + ib - 1, p)
    if kind == "same":
        return feat
    if kind == "valid":
        return feat - filt + 1
    if kind == "full":
        return feat + filt - 1
    raise ValueError(f"unknown conv kind {kind}")


@dataclass
class Sized:
    """Spec with dims bound; default conv kinds mirror rust SizedSpec::new."""

    spec: Spec
    dims: list[list[int]]
    conv_kinds: list[str] = field(default_factory=list)

    def __post_init__(self):
        assert len(self.dims) == len(self.spec.inputs)
        for modes, sizes in zip(self.spec.inputs, self.dims):
            assert len(modes) == len(sizes), (modes, sizes)
        if not self.conv_kinds:
            self.conv_kinds = [
                "circular" if self.spec.occurrences(m) > 2 else "same"
                for m in self.spec.conv
            ]
        # non-conv shared modes must agree
        for m in self.spec.all_modes():
            if m in self.spec.conv:
                continue
            sizes = self.occurrence_sizes(m)
            if len(set(sizes)) > 1:
                raise ValueError(f"mode {m!r} has inconsistent sizes {sizes}")

    def occurrence_sizes(self, m: str) -> list[int]:
        out = []
        for modes, sizes in zip(self.spec.inputs, self.dims):
            if m in modes:
                out.append(sizes[modes.index(m)])
        return out

    def mode_size(self, m: str) -> int:
        return self.occurrence_sizes(m)[0]

    def conv_feature(self, m: str) -> int:
        return max(self.occurrence_sizes(m))

    def conv_kind(self, m: str) -> str:
        return self.conv_kinds[self.spec.conv.index(m)]

    def output_shape(self) -> list[int]:
        shape = []
        for m in self.spec.output:
            if m in self.spec.conv:
                sizes = self.occurrence_sizes(m)
                if len(sizes) == 1:
                    shape.append(sizes[0])
                elif len(sizes) == 2:
                    shape.append(conv_out_size(self.conv_kind(m), sizes[0], sizes[1], None))
                else:
                    shape.append(self.conv_feature(m))
            else:
                shape.append(self.mode_size(m))
        return shape


# ---------------------------------------------------------------------------
# Optimal sequencer (mirrors rust planner subset-DP)
# ---------------------------------------------------------------------------

@dataclass
class SubSpec:
    mask: int
    modes: list[str]
    sizes: list[int]

    def size_of(self, m: str) -> int | None:
        return self.sizes[self.modes.index(m)] if m in self.modes else None

    def elems(self) -> float:
        return float(math.prod(self.sizes))


class Ctx:
    def __init__(self, sized: Sized):
        self.sized = sized
        self.spec = sized.spec
        self.occ_mask = {}
        for i, modes in enumerate(self.spec.inputs):
            for m in modes:
                self.occ_mask[m] = self.occ_mask.get(m, 0) | (1 << i)
        self.out_set = set(self.spec.output)
        self.conv_feature = {m: sized.conv_feature(m) for m in self.spec.conv}

    def needed_outside(self, m: str, mask: int) -> bool:
        return m in self.out_set or (self.occ_mask[m] & ~mask) != 0

    def leaf(self, i: int) -> SubSpec:
        return SubSpec(1 << i, list(self.spec.inputs[i]), list(self.sized.dims[i]))

    def mode_size_in(self, m: str, mask: int) -> int:
        if m not in self.spec.conv:
            return self.sized.mode_size(m)
        inside = []
        for i, modes in enumerate(self.spec.inputs):
            if mask & (1 << i) and m in modes:
                inside.append(self.sized.dims[i][modes.index(m)])
        if len(inside) == 1:
            return inside[0]
        kind = self.sized.conv_kind(m)
        if kind == "circular":
            return min(sum(inside) - (len(inside) - 1), self.conv_feature[m])
        return conv_out_size(kind, inside[0], inside[1], None)

    def subset(self, mask: int) -> SubSpec:
        if bin(mask).count("1") == 1:
            return self.leaf(mask.bit_length() - 1)
        modes = []
        for m in self.spec.all_modes():
            occ = self.occ_mask.get(m, 0)
            if occ & mask == 0:
                continue
            if self.needed_outside(m, mask) or m in self.spec.conv:
                modes.append(m)
        modes.sort()
        sizes = [self.mode_size_in(m, mask) for m in modes]
        return SubSpec(mask, modes, sizes)

    def merge_cost_and_out(self, a: SubSpec, b: SubSpec, training: bool):
        """(cost_mults, out_elems) of the pairwise merge — Appendix B."""
        union = a.mask | b.mask
        g = t = n = s = 1.0
        conv = []  # (ia, ib, io)
        for m in sorted(set(a.modes) | set(b.modes)):
            sa, sb = a.size_of(m), b.size_of(m)
            needed = self.needed_outside(m, union)
            is_conv = m in self.spec.conv
            if sa is not None and sb is not None:
                if is_conv:
                    kind = self.sized.conv_kind(m)
                    modulus = self.conv_feature[m] if kind == "circular" else None
                    conv.append((sa, sb, conv_out_size(kind, sa, sb, modulus)))
                elif needed:
                    g *= sa
                else:
                    s *= sa
            elif sa is not None:
                if needed or is_conv:
                    t *= sa
            else:
                if needed or is_conv:
                    n *= sb
        fwd = g * t * n * s * math.prod(ia * ib for ia, ib, _ in conv)
        if training:
            g1 = g * t * n * s * math.prod(io * ib for _, ib, io in conv)
            g2 = g * t * n * s * math.prod(io * ia for ia, _, io in conv)
            cost = fwd + g1 + g2
        else:
            cost = fwd
        out_elems = g * t * n * math.prod(io for _, _, io in conv)
        return cost, out_elems


def _ltr_cost(ctx: Ctx, n: int, training: bool) -> float:
    total = 0.0
    acc = 1
    for i in range(1, n):
        a = ctx.subset(acc)
        b = ctx.leaf(i)
        c, _ = ctx.merge_cost_and_out(a, b, training)
        total += c
        acc |= 1 << i
    return total


def contract_path(expr: str, dims: list[list[int]], training: bool = False) -> dict:
    """Plan an N-input conv_einsum; mirrors rust `contract_path` costs.

    Returns a dict with keys cost, naive_cost, largest_intermediate and
    steps: a list of (left_mask, right_mask) merges in bottom-up order.
    """
    spec = parse(expr)
    sized = Sized(spec, [list(d) for d in dims])
    ctx = Ctx(sized)
    n = len(spec.inputs)
    if n < 2:
        raise ValueError("need at least 2 inputs")
    full = (1 << n) - 1

    best = {1 << i: 0.0 for i in range(n)}
    split: dict[int, tuple[int, int]] = {}
    subs = {1 << i: ctx.leaf(i) for i in range(n)}

    for mask in range(3, full + 1):
        if bin(mask).count("1") < 2:
            continue
        subs[mask] = ctx.subset(mask)
        low = mask & (-mask)
        sub = (mask - 1) & mask
        b_cost = math.inf
        b_split = None
        while sub:
            if sub & low:
                other = mask ^ sub
                if sub in best and other in best:
                    c, _ = ctx.merge_cost_and_out(subs[sub], subs[other], training)
                    cand = best[sub] + best[other] + c
                    if cand < b_cost:
                        b_cost = cand
                        b_split = (sub, other)
            sub = (sub - 1) & mask
        best[mask] = b_cost
        split[mask] = b_split

    # reconstruct
    steps = []
    largest = 0.0

    def emit(mask):
        nonlocal largest
        if bin(mask).count("1") == 1:
            return
        l, r = split[mask]
        emit(l)
        emit(r)
        _, out_elems = ctx.merge_cost_and_out(subs[l], subs[r], training)
        largest = max(largest, out_elems)
        steps.append((l, r))

    emit(full)

    return {
        "expr": spec.render(),
        "cost": best[full],
        "naive_cost": _ltr_cost(ctx, n, training),
        "largest_intermediate": largest,
        "steps": steps,
        "n_inputs": n,
    }


def optimal_order(expr: str, dims: list[list[int]]) -> list[tuple[int, int]]:
    """The optimal merge order as (left_mask, right_mask) pairs."""
    return contract_path(expr, dims)["steps"]
