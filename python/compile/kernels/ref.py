"""Correctness oracles for the kernels (build-time only).

`pairwise_ref` is a brute-force numpy evaluator for a 2-input conv_einsum
with the exact semantics of the rust executor (true convolution; same /
valid / full / circular varieties; see rust/src/exec/reference.rs). The
Pallas kernels and the JAX model path are validated against it by pytest +
hypothesis.
"""

from __future__ import annotations

import itertools

import numpy as np


def conv_index(kind: str, p_full: int, feat: int, filt: int, out: int):
    """Map a full-conv output index to the variety's output index (or None)."""
    if kind == "full":
        return p_full
    if kind == "circular":
        return p_full % max(feat, 1) % max(out, 1)
    shift = (filt - 1) // 2 if kind == "same" else filt - 1
    p = p_full - shift
    return p if 0 <= p < out else None


def out_size(kind: str, ia: int, ib: int) -> int:
    feat, filt = max(ia, ib), min(ia, ib)
    if kind == "full":
        return ia + ib - 1
    if kind == "valid":
        return feat - filt + 1
    return feat  # same / circular


def pairwise_ref(
    lhs_modes: list[str],
    rhs_modes: list[str],
    out_modes: list[str],
    conv_modes: list[str],
    a: np.ndarray,
    b: np.ndarray,
    kinds: dict[str, str] | None = None,
) -> np.ndarray:
    """Brute-force 2-input conv_einsum. Exponential; test sizes only."""
    kinds = kinds or {}
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    sizes_a = dict(zip(lhs_modes, a.shape))
    sizes_b = dict(zip(rhs_modes, b.shape))

    def kind_of(m):
        return kinds.get(m, "same")

    out_shape = []
    for m in out_modes:
        if m in conv_modes and m in sizes_a and m in sizes_b:
            out_shape.append(out_size(kind_of(m), sizes_a[m], sizes_b[m]))
        else:
            out_shape.append(sizes_a.get(m, sizes_b.get(m)))
    out = np.zeros(out_shape)

    # enumeration axes: shared index per non-conv mode, separate per conv occ
    shared = [m for m in dict.fromkeys(lhs_modes + rhs_modes) if m not in conv_modes]
    conv_both = [m for m in conv_modes if m in sizes_a and m in sizes_b]
    conv_single = [m for m in conv_modes if m not in conv_both]

    ranges = []
    names = []
    for m in shared:
        ranges.append(range(sizes_a.get(m, sizes_b.get(m))))
        names.append(("shared", m))
    for m in conv_both:
        ranges.append(range(sizes_a[m]))
        names.append(("conv_a", m))
        ranges.append(range(sizes_b[m]))
        names.append(("conv_b", m))
    for m in conv_single:
        ranges.append(range(sizes_a.get(m, sizes_b.get(m))))
        names.append(("shared", m))

    for combo in itertools.product(*ranges):
        env = dict(zip(names, combo))
        ok = True
        oix = []
        for m in out_modes:
            if m in conv_both:
                ia = env[("conv_a", m)]
                ib = env[("conv_b", m)]
                feat = max(sizes_a[m], sizes_b[m])
                filt = min(sizes_a[m], sizes_b[m])
                osz = out_size(kind_of(m), sizes_a[m], sizes_b[m])
                p = conv_index(kind_of(m), ia + ib, feat, filt, osz)
                if p is None:
                    ok = False
                    break
                oix.append(p)
            else:
                oix.append(env[("shared", m)])
        if not ok:
            continue
        aix = tuple(
            env[("conv_a", m)] if m in conv_both else env[("shared", m)]
            for m in lhs_modes
        )
        bix = tuple(
            env[("conv_b", m)] if m in conv_both else env[("shared", m)]
            for m in rhs_modes
        )
        out[tuple(oix)] += a[aix] * b[bix]
    return out


def matmul_atom_ref(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """out[g,t,n] = sum_s a[g,t,s] * b[g,n,s]."""
    return np.einsum("gts,gns->gtn", a, b)


def conv2d_atom_ref(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Grouped 2-D true-convolution atom, Same padding.

    a: [G, T, S, HA, WA] (feature), b: [G, N, S, HB, WB] (filter),
    out: [G, T, N, HA, WA]; out[..., p] = sum_{i+j=p+shift} a[i] b[j].
    """
    g, t, s, ha, wa = a.shape
    g2, n, s2, hb, wb = b.shape
    assert g == g2 and s == s2 and ha >= hb and wa >= wb
    sh, sw = (hb - 1) // 2, (wb - 1) // 2
    out = np.zeros((g, t, n, ha, wa))
    apad = np.pad(a, ((0, 0), (0, 0), (0, 0), (hb - 1, hb - 1), (wb - 1, wb - 1)))
    for i in range(hb):
        for j in range(wb):
            # a index = p + shift - i  ⇒ padded offset (shift - i + hb - 1)
            off_h = sh - i + hb - 1
            off_w = sw - j + wb - 1
            window = apad[:, :, :, off_h : off_h + ha, off_w : off_w + wa]
            out += np.einsum("gtshw,gns->gtnhw", window, b[:, :, :, i, j])
    return out
