"""Layer-1 Pallas kernels: the paper's §3.1 atomic operations.

Two kernels cover every pairwise step of a planned conv_einsum path:

* `matmul_atom` — the pure contraction/batch/outer atom
  `out[g,t,n] = Σ_s a[g,t,s]·b[g,n,s]` (conv1d's non-conv special case);
* `conv2d_atom` — the grouped 2-D true-convolution atom with Same padding
  (the conv2d case of §3.1, `"gtshw,bgshw->bgthw|h,w"` up to mode order).

HARDWARE ADAPTATION (DESIGN.md §6): on TPU the atom is an MXU contraction
over VMEM-resident tiles. The grid iterates (G, T-tiles); each program
holds one `[TS_TILE, S, HA, WA]` feature block and the full filter block in
VMEM and reduces over S and the filter taps with `jnp.einsum` (lowered to
MXU dots). `interpret=True` is mandatory here: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so correctness runs through the interpreter
and real-TPU performance is *estimated* from the BlockSpec footprint
(see EXPERIMENTS.md §Perf/L1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget used by the block-shape heuristic (bytes). A v4 core has
# ~16 MiB; leave headroom for double buffering.
VMEM_BUDGET = 8 * 1024 * 1024


def _matmul_kernel(a_ref, b_ref, o_ref):
    # a: [1, T, S], b: [1, N, S] → o: [1, T, N]; contraction on the MXU.
    a = a_ref[0]
    b = b_ref[0]
    o_ref[0] = jnp.dot(a, b.T, preferred_element_type=jnp.float32)


def matmul_atom(a: jax.Array, b: jax.Array) -> jax.Array:
    """out[g,t,n] = Σ_s a[g,t,s] b[g,n,s] via a Pallas grid over G."""
    g, t, s = a.shape
    g2, n, s2 = b.shape
    assert g == g2 and s == s2, (a.shape, b.shape)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, t, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, s), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, t, n), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


def _conv2d_kernel(hb, wb, sh, sw, a_ref, b_ref, o_ref):
    # a: [1, TT, S, HA+2(hb-1), WA+2(wb-1)] pre-padded feature tile
    # b: [1, N, S, HB, WB] filter
    # o: [1, TT, N, HA, WA]
    a = a_ref[0]
    b = b_ref[0]
    tt, s, hp, wp = a.shape
    ha = hp - 2 * (hb - 1)
    wa = wp - 2 * (wb - 1)
    acc = jnp.zeros((tt, b.shape[0], ha, wa), jnp.float32)
    # True convolution, Same padding: out[p] = Σ_{i} b[i]·a[p + shift − i],
    # realized as static slices of the pre-padded feature (unrolled taps —
    # each tap is one MXU-shaped contraction over S).
    for i in range(hb):
        for j in range(wb):
            off_h = sh - i + hb - 1
            off_w = sw - j + wb - 1
            window = jax.lax.slice(
                a, (0, 0, off_h, off_w), (tt, s, off_h + ha, off_w + wa)
            )
            acc = acc + jnp.einsum(
                "tshw,ns->tnhw", window, b[:, :, i, j],
                preferred_element_type=jnp.float32,
            )
    o_ref[0] = acc


def conv2d_atom(a: jax.Array, b: jax.Array) -> jax.Array:
    """Grouped 2-D true-convolution atom, Same padding.

    a: [G, T, S, HA, WA] feature; b: [G, N, S, HB, WB] filter
    (HB ≤ HA, WB ≤ WA); out: [G, T, N, HA, WA].
    """
    g, t, s, ha, wa = a.shape
    g2, n, s2, hb, wb = b.shape
    assert g == g2 and s == s2 and hb <= ha and wb <= wa, (a.shape, b.shape)
    sh, sw = (hb - 1) // 2, (wb - 1) // 2
    # Pre-pad the feature so every tap is a static in-bounds slice.
    apad = jnp.pad(
        a.astype(jnp.float32),
        ((0, 0), (0, 0), (0, 0), (hb - 1, hb - 1), (wb - 1, wb - 1)),
    )
    hp, wp = ha + 2 * (hb - 1), wa + 2 * (wb - 1)
    # T tiling keeps the VMEM footprint bounded (see vmem_footprint).
    tt = t_tile(t, s, hp, wp, n, hb, wb)
    grid_t = (t + tt - 1) // tt
    if t % tt != 0:
        pad_t = grid_t * tt - t
        apad = jnp.pad(apad, ((0, 0), (0, pad_t), (0, 0), (0, 0), (0, 0)))
    kernel = functools.partial(_conv2d_kernel, hb, wb, sh, sw)
    out = pl.pallas_call(
        kernel,
        grid=(g, grid_t),
        in_specs=[
            pl.BlockSpec((1, tt, s, hp, wp), lambda gi, ti: (gi, ti, 0, 0, 0)),
            pl.BlockSpec((1, n, s, hb, wb), lambda gi, ti: (gi, 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tt, n, ha, wa), lambda gi, ti: (gi, ti, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, grid_t * tt, n, ha, wa), jnp.float32),
        interpret=True,
    )(apad, b.astype(jnp.float32))
    return out[:, :t]


def t_tile(t: int, s: int, hp: int, wp: int, n: int, hb: int, wb: int) -> int:
    """Largest T-tile whose VMEM footprint fits the budget."""
    for tt in range(t, 0, -1):
        if vmem_footprint(tt, s, hp, wp, n, hb, wb) <= VMEM_BUDGET:
            return tt
    return 1


def vmem_footprint(tt: int, s: int, hp: int, wp: int, n: int, hb: int, wb: int) -> int:
    """Bytes resident per program: feature tile + filter + accumulator.

    This is the L1 performance model used by EXPERIMENTS.md §Perf — on a
    real TPU the tile must fit VMEM; MXU utilization is estimated as the
    fraction of the contraction (S·HB·WB per output element) that lands in
    128×128 systolic passes.
    """
    feat = tt * s * hp * wp * 4
    filt = n * s * hb * wb * 4
    ha, wa = hp - 2 * (hb - 1), wp - 2 * (wb - 1)
    acc = tt * n * ha * wa * 4
    return feat + filt + acc


def mxu_utilization_estimate(t: int, s: int, n: int) -> float:
    """Fraction of MXU lanes busy for the per-tap contraction
    `[T,S]×[N,S]→[T,N]`: each dimension utilizes min(dim,128)/128 lanes."""
    use = lambda d: min(d, 128) / 128.0
    return use(t) * use(s) * use(n)
