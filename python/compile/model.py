"""Layer-2 JAX model: tensorial layer forward passes and a train step,
structured by a planner-chosen pairwise evaluation path.

The multilinear structure (which pairs merge, in what order) comes from
`compile.conv_einsum.contract_path`; every pairwise step canonicalizes to
the §3.1 atom and dispatches to the Layer-1 Pallas kernels
(`kernels.conv_atom`) or, on the differentiable path used by `train_step`,
to pure-jnp equivalents (Pallas interpret-mode calls are not
differentiable, so the AOT'd train step uses the jnp atoms with the same
planned order).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .conv_einsum import Ctx, Sized, contract_path, parse
from .kernels import conv_atom as pallas_kernels


# ---------------------------------------------------------------------------
# Pairwise atom dispatch
# ---------------------------------------------------------------------------

@dataclass
class StepSpec:
    lhs_modes: list[str]
    rhs_modes: list[str]
    out_modes: list[str]
    conv_modes: list[str]  # conv modes present in both operands


def _canonical(step: StepSpec, a: jax.Array, b: jax.Array, use_pallas: bool):
    """Canonicalize to the atom layout, execute, restore mode order.

    Supports contraction/batch/outer atoms and ≤2 Same-padded conv modes —
    exactly what tensorial layer forward paths need.
    """
    lm, rm, om = step.lhs_modes, step.rhs_modes, step.out_modes
    conv = [m for m in step.conv_modes if m in lm and m in rm]
    assert len(conv) <= 2, "layer paths use at most hw convolution pairs"

    in_a = set(lm)
    in_b = set(rm)
    in_o = set(om)
    batch = [m for m in lm if m in in_b and m in in_o and m not in conv]
    contr = [m for m in lm if m in in_b and m not in in_o and m not in conv]
    afree = [m for m in lm if m not in in_b and m not in conv]
    bfree = [m for m in rm if m not in in_a and m not in conv]
    # self-sum modes (not in output) get summed by putting them in contr of
    # one side only — layer expressions do not produce them, assert instead:
    assert all(m in in_o for m in afree + bfree), "unexpected self-sum mode"

    sa = dict(zip(lm, a.shape))
    sb = dict(zip(rm, b.shape))

    perm_a = [lm.index(m) for m in batch + afree + contr + conv]
    perm_b = [rm.index(m) for m in batch + bfree + contr + conv]
    at = jnp.transpose(a, perm_a)
    bt = jnp.transpose(b, perm_b)

    G = math.prod(sa[m] for m in batch)
    T = math.prod(sa[m] for m in afree)
    N = math.prod(sb[m] for m in bfree)
    S = math.prod(sa[m] for m in contr)

    if not conv:
        ac = at.reshape(G, T, S)
        bc = bt.reshape(G, N, S)
        raw = (
            pallas_kernels.matmul_atom(ac, bc)
            if use_pallas
            else jnp.einsum("gts,gns->gtn", ac, bc)
        )
        raw_dims = (
            [sa[m] for m in batch] + [sa[m] for m in afree] + [sb[m] for m in bfree]
        )
        conv_out = []
    else:
        # normalize to 2 conv axes (insert singleton when only one)
        ca = [sa[m] for m in conv]
        cb = [sb[m] for m in conv]
        if len(conv) == 1:
            ca = ca + [1]
            cb = cb + [1]
        # feature must be on the `a` side for the kernel: swap if needed
        swapped = any(x < y for x, y in zip(ca, cb))
        if swapped:
            at, bt = bt, at
            T, N = N, T
            afree, bfree = bfree, afree
            sa, sb = sb, sa
            ca, cb = cb, ca
        assert all(x >= y for x, y in zip(ca, cb)), "mixed feature sides"
        ac = at.reshape(G, T, S, *ca)
        bc = bt.reshape(G, N, S, *cb)
        raw = (
            pallas_kernels.conv2d_atom(ac, bc)
            if use_pallas
            else _conv2d_atom_jnp(ac, bc)
        )
        conv_out = list(raw.shape[3:])
        if len(conv) == 1:
            raw = raw.reshape(*raw.shape[:-2], raw.shape[-2])
            conv_out = conv_out[:1]
        raw_dims = (
            [sa[m] for m in batch]
            + [sa[m] for m in afree]
            + [sb[m] for m in bfree]
            + conv_out
        )

    raw_modes = batch + afree + bfree + conv
    raw = raw.reshape(raw_dims)
    out_perm = [raw_modes.index(m) for m in om]
    return jnp.transpose(raw, out_perm)


def _conv2d_atom_jnp(a: jax.Array, b: jax.Array) -> jax.Array:
    """Differentiable jnp twin of the Pallas conv2d atom (Same, true conv)."""
    g, t, s, ha, wa = a.shape
    _, n, _, hb, wb = b.shape
    sh, sw = (hb - 1) // 2, (wb - 1) // 2
    apad = jnp.pad(a, ((0, 0), (0, 0), (0, 0), (hb - 1, hb - 1), (wb - 1, wb - 1)))
    acc = jnp.zeros((g, t, n, ha, wa), a.dtype)
    for i in range(hb):
        for j in range(wb):
            off_h = sh - i + hb - 1
            off_w = sw - j + wb - 1
            window = jax.lax.slice(
                apad, (0, 0, 0, off_h, off_w), (g, t, s, off_h + ha, off_w + wa)
            )
            acc = acc + jnp.einsum("gtshw,gns->gtnhw", window, b[:, :, :, i, j])
    return acc


# ---------------------------------------------------------------------------
# Path execution
# ---------------------------------------------------------------------------

def build_steps(expr: str, dims: list[list[int]], order=None):
    """Resolve the merge order into executable StepSpecs.

    `order` is a list of (left_mask, right_mask); default = optimal path.
    """
    spec = parse(expr)
    sized = Sized(spec, [list(d) for d in dims])
    ctx = Ctx(sized)
    if order is None:
        order = contract_path(expr, dims)["steps"]
    steps = []
    for l, r in order:
        a = ctx.subset(l)
        b = ctx.subset(r)
        merged = ctx.subset(l | r)
        conv = [m for m in spec.conv if m in a.modes and m in b.modes]
        steps.append((l, r, StepSpec(a.modes, b.modes, merged.modes, conv)))
    # final permutation: merged root (sorted) → requested output
    root = ctx.subset((1 << len(spec.inputs)) - 1)
    final_perm = [root.modes.index(m) for m in spec.output]
    return steps, final_perm


def ltr_order(n: int):
    """Left-to-right merge order (the paper's naive baseline)."""
    order = []
    acc = 1
    for i in range(1, n):
        order.append((acc, 1 << i))
        acc |= 1 << i
    return order


def path_forward(expr: str, dims: list[list[int]], order=None, use_pallas=True):
    """Return f(*tensors) executing the expression along the given path."""
    steps, final_perm = build_steps(expr, dims, order)

    def f(*tensors):
        vals = {1 << i: t for i, t in enumerate(tensors)}
        for l, r, step in steps:
            vals[l | r] = _canonical(step, vals.pop(l), vals.pop(r), use_pallas)
        (root,) = vals.values()
        return jnp.transpose(root, final_perm)

    return f


# ---------------------------------------------------------------------------
# Layer + train step builders (the AOT entry points)
# ---------------------------------------------------------------------------

def tnn_layer_forward(expr: str, dims: list[list[int]], strategy="optimal",
                      use_pallas=True):
    """Forward function for a tensorial layer expression."""
    n = len(dims)
    order = None if strategy == "optimal" else ltr_order(n)
    return path_forward(expr, dims, order, use_pallas=use_pallas)


def tiny_tnn_train_step(expr: str, dims: list[list[int]], n_classes: int,
                        lr: float = 0.05, strategy="optimal"):
    """A full SGD train step for a tiny tensorial classifier.

    Model: tensorial conv layer (planned path, jnp atoms for AD) → global
    average pool → linear head → softmax cross-entropy. Returns
    `step(x, labels_onehot, *factors, w, b) -> (loss, new_params...)`.
    """
    n = len(dims)
    order = None if strategy == "optimal" else ltr_order(n)
    layer = path_forward(expr, dims, order, use_pallas=False)

    def loss_fn(params, x, labels_onehot):
        factors, w, b = params[:-2], params[-2], params[-1]
        y = layer(x, *factors)  # [B, T..., H, W]
        bsz = y.shape[0]
        feats = y.reshape(bsz, -1, *y.shape[-2:]).mean(axis=(2, 3))
        logits = feats @ w + b
        logz = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
        logp = logits - logz
        return -(labels_onehot * logp).sum(axis=-1).mean()

    def step(x, labels_onehot, *params):
        loss, grads = jax.value_and_grad(loss_fn)(list(params), x, labels_onehot)
        new = [p - lr * g for p, g in zip(params, grads)]
        return (loss, *new)

    return step
