"""AOT pipeline: lower the L2 JAX functions (with L1 Pallas kernels inside)
to HLO **text** artifacts for the rust runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the pinned xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md and DESIGN.md).

Run via `make artifacts`:  python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, arg_shapes):
    args = [jax.ShapeDtypeStruct(tuple(s), jnp.float32) for s in arg_shapes]
    return jax.jit(fn).lower(*args)


def build_manifest_entries():
    """The artifact catalogue: every L2 function the rust layer loads."""
    entries = []

    # --- CP layer forward (flat), Pallas kernels, optimal vs naive path ---
    cp_expr = "bshw,rt,rs,rh,rw->bthw|hw"
    cp_dims = [[4, 8, 16, 16], [6, 8], [6, 8], [6, 3], [6, 3]]
    for strategy in ("optimal", "ltr"):
        fn = model_lib.tnn_layer_forward(cp_expr, cp_dims, strategy=strategy)
        entries.append(
            dict(
                name=f"cp_layer_fwd_{strategy}",
                fn=lambda *a, fn=fn: (fn(*a),),
                input_shapes=cp_dims,
                description=f"CP conv layer forward, {strategy} path, Pallas atoms",
            )
        )

    # --- RCP (M=2) layer forward, Pallas kernels ---
    rcp_expr = "b(s1)(s2)hw,r(t1)(s1),r(t2)(s2),rhw->b(t1)(t2)hw|hw"
    rcp_dims = [[2, 3, 4, 12, 12], [5, 3, 3], [5, 2, 4], [5, 3, 3]]
    fn = model_lib.tnn_layer_forward(rcp_expr, rcp_dims, strategy="optimal")
    entries.append(
        dict(
            name="rcp_layer_fwd_optimal",
            fn=lambda *a, fn=fn: (fn(*a),),
            input_shapes=rcp_dims,
            description="reshaped-CP (M=2) layer forward, optimal path, Pallas atoms",
        )
    )

    # --- tiny TNN train step (jnp atoms, optimal order baked) ---
    ts_expr = "bshw,rt,rs,rh,rw->bthw|hw"
    ts_dims = [[8, 4, 12, 12], [4, 6], [4, 4], [4, 3], [4, 3]]
    n_classes = 4
    step = model_lib.tiny_tnn_train_step(ts_expr, ts_dims, n_classes)
    t_out = ts_dims[1][1]
    step_shapes = (
        [ts_dims[0], [8, n_classes]]
        + ts_dims[1:]
        + [[t_out, n_classes], [n_classes]]
    )
    entries.append(
        dict(
            name="tnn_train_step",
            fn=step,
            input_shapes=step_shapes,
            description=(
                "SGD train step for a tiny CP-TNN classifier "
                "(loss + updated params), optimal path order"
            ),
        )
    )
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for entry in build_manifest_entries():
        lowered = lower_fn(entry["fn"], entry["input_shapes"])
        text = to_hlo_text(lowered)
        fname = f"{entry['name']}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        # output shape: evaluate abstractly
        out_aval = jax.eval_shape(
            entry["fn"],
            *[jax.ShapeDtypeStruct(tuple(s), jnp.float32) for s in entry["input_shapes"]],
        )
        first = jax.tree_util.tree_leaves(out_aval)[0]
        manifest.append(
            dict(
                name=entry["name"],
                file=fname,
                input_shapes=entry["input_shapes"],
                output_shape=list(first.shape),
                description=entry["description"],
            )
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=2)
    print(f"wrote manifest with {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
